"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real (1-device) backend; mesh integration tests spawn
subprocesses with their own flags."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tree_allclose(a, b, atol=1e-5, rtol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if not jnp.allclose(x, y, atol=atol, rtol=rtol):
            return False
    return True
