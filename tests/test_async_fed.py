"""Deterministic churn harness for the buffered-async driver.

Every behaviour of core/async_fed.py is pinned here against the seeded
virtual-clock event model (data/churn.py): bitwise same-seed replay,
bitwise degenerate equivalence with the synchronous ``round_scan``,
fault injection (drops / stale discards leave per-client compressor
state untouched and unbilled), buffer semantics (a server step happens
at exactly K updates, never fewer), staleness-weighting properties, and
scan <-> shard_map composition under churn.  Replay-from-seed debugging
recipe: docs/async.md.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core import FedConfig, fed_init, make_fl_round
from repro.core import comm
from repro.core import sparsify as S
from repro.core.async_fed import (AsyncConfig, make_async_round,
                                  staleness_scale, staleness_weights)
from repro.data.churn import ChurnConfig, ChurnModel, ClientFate
from repro.optim import AdamHyper

pytestmark = pytest.mark.churn

_REPO = Path(__file__).resolve().parents[1]


def _toy(C=4):
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4)) * 0.1,
              "b": jnp.zeros((4,))}
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, 16, 8))
    w_true = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    ys = jnp.einsum("cbi,ij->cbj", xs, w_true)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    return params, (xs, ys), loss_fn


def _fed(C=4, **kw):
    kw.setdefault("algorithm", "fedadam_ssm")
    kw.setdefault("error_feedback", True)
    return FedConfig(alpha=0.3, local_epochs=2, n_clients=C,
                     adam=AdamHyper(lr=0.05), **kw)


def _biteq(ta, tb):
    la, lb = jax.tree.leaves(ta), jax.tree.leaves(tb)
    assert len(la) == len(lb)
    return all(bool(jnp.all(a == b)) for a, b in zip(la, lb))


# ---------------------------------------------------------------------------
# Replay + degenerate equivalence (the two acceptance anchors)
# ---------------------------------------------------------------------------


def test_same_seed_bitwise_replay():
    """Same ChurnConfig seed => the full simulation replays bitwise:
    event log, final params, per-client EF state, uplink_bits."""
    C = 6
    params, batches, loss_fn = _toy(C)
    fed = _fed(C)
    cc = ChurnConfig(seed=3, jitter=5, straggler_prob=0.3, drop_prob=0.2,
                     rejoin_delay=2)
    acfg = AsyncConfig(buffer_size=3, max_staleness=2)

    def go():
        run = make_async_round(fed, loss_fn, acfg,
                               churn=ChurnModel(cc, C))
        return run(fed_init(fed, params), batches, rounds=5)

    s1, m1 = go()
    s2, m2 = go()
    assert m1["events"] == m2["events"]
    assert m1["server_steps"] == 5
    # churn actually exercised something this seed
    assert m1["dropped"] > 0 and m1["discarded"] > 0
    assert float(m1["uplink_bits"]) == float(m2["uplink_bits"])
    assert _biteq(s1, s2)  # W, M, V, round, and all per-client state


def test_degenerate_config_matches_round_scan_bitwise():
    """Zero churn + buffer == cohort + staleness weight == 1 collapses
    the async driver onto the synchronous barrier: 3 rounds must match
    ``round_scan`` BIT-identically (params, moments, per-client EF
    state, round counter, and uplink accounting)."""
    C = 4
    params, batches, loss_fn = _toy(C)
    fed = _fed(C)

    rf = jax.jit(make_fl_round(fed, loss_fn))
    st = fed_init(fed, params)
    sync_bits = 0.0
    for _ in range(3):
        st, mets = rf(st, batches)
        sync_bits += float(mets["uplink_bits"])

    run = make_async_round(fed, loss_fn, AsyncConfig(buffer_size=C),
                           churn=ChurnModel(ChurnConfig(), C))
    ast, amets = run(fed_init(fed, params), batches, rounds=3)

    assert amets["server_steps"] == 3
    assert amets["landed"] == 3 * C
    assert float(amets["uplink_bits"]) == sync_bits
    assert _biteq(st.W, ast.W)
    assert _biteq(st.M, ast.M)
    assert _biteq(st.V, ast.V)
    assert _biteq(st.client_state, ast.client_state)
    assert int(st.round) == int(ast.round) == 3


def test_async_state_is_sync_checkpoint_compatible():
    """The async driver consumes/produces the same FedState as the sync
    round: sync round 1 -> async round 2 runs and advances the clock."""
    C = 4
    params, batches, loss_fn = _toy(C)
    fed = _fed(C)
    rf = jax.jit(make_fl_round(fed, loss_fn))
    st, _ = rf(fed_init(fed, params), batches)
    run = make_async_round(fed, loss_fn, AsyncConfig(buffer_size=C),
                           churn=ChurnModel(ChurnConfig(), C))
    ast, mets = run(st, batches, rounds=1)
    assert mets["server_steps"] == 1 and int(ast.round) == 2


# ---------------------------------------------------------------------------
# Fault injection (scripted fates)
# ---------------------------------------------------------------------------


def _warm_state(fed, params, batches, loss_fn):
    """One clean async round so EF residuals are nonzero before the
    fault is injected (untouched-vs-zeros would be a vacuous check)."""
    run = make_async_round(fed, loss_fn,
                           AsyncConfig(buffer_size=fed.n_clients),
                           churn=ChurnModel(ChurnConfig(), fed.n_clients))
    st, _ = run(fed_init(fed, params), batches, rounds=1)
    err = st.client_state["comp"]["err"]
    assert max(float(jnp.max(jnp.abs(x)))
               for x in jax.tree.leaves(err)) > 0
    return st


def test_drop_after_compress_preserves_state_and_bits():
    """A client whose update is lost after compress but before delivery
    keeps its EF residual bitwise intact (never rezeroed — the
    Efficient-Adam lesson) and its bits are NOT billed."""
    C = 4
    params, batches, loss_fn = _toy(C)
    fed = _fed(C)
    st0 = _warm_state(fed, params, batches, loss_fn)

    victim = 1
    churn = ChurnModel(ChurnConfig(), C,
                       script={(victim, 0): ClientFate(8, drop=True)})
    run = make_async_round(fed, loss_fn, AsyncConfig(buffer_size=C - 1),
                           churn=churn)
    st1, mets = run(st0, batches, rounds=1)

    assert mets["dropped"] == 1 and mets["landed"] == C - 1
    pick = lambda cs, c: jax.tree.map(lambda x: x[c], cs)
    # the dropped client's whole per-client state is bitwise untouched
    assert _biteq(pick(st0.client_state, victim),
                  pick(st1.client_state, victim))
    # the survivors' residuals did move
    for c in range(C):
        if c != victim:
            assert not _biteq(pick(st0.client_state, c),
                              pick(st1.client_state, c))
    # bits: only landed updates are billed, and they match comm.bits_for
    d = sum(x.size for x in jax.tree.leaves(st0.W))
    sizes = tuple(x.size for x in jax.tree.leaves(st0.W))
    per_client = comm.bits_for(fed.algorithm, d, S.k_for(d, fed.alpha),
                               1, 32, sizes=sizes, alpha=fed.alpha)
    assert float(mets["uplink_bits"]) == (C - 1) * float(per_client)


def test_stale_straggler_discarded_with_same_guarantees():
    """An update older than max_staleness at arrival is discarded: state
    untouched bitwise, bits unbilled — exactly like a drop."""
    C = 4
    params, batches, loss_fn = _toy(C)
    fed = _fed(C)
    st0 = _warm_state(fed, params, batches, loss_fn)

    victim = 2
    # base_duration=8: the pack arrives at t=8,16,24...; the victim's
    # attempt-0 update arrives at t=20 with snapshot version 0 while the
    # server is already 2 steps ahead
    churn = ChurnModel(ChurnConfig(), C,
                       script={(victim, 0): ClientFate(20, drop=False)})
    run = make_async_round(fed, loss_fn,
                           AsyncConfig(buffer_size=C - 1, max_staleness=0),
                           churn=churn)
    st1, mets = run(st0, batches, rounds=3)

    assert mets["discarded"] >= 1
    discards = [e for e in mets["events"] if e[1] == "discard"]
    assert any(e[2] == victim and e[3] == 2 for e in discards)
    # victim state frozen through its discard window: replay the sim and
    # stop before the victim's redispatched update ever lands
    landed_victim = [e for e in mets["events"]
                     if e[1] == "deliver" and e[2] == victim]
    d = sum(x.size for x in jax.tree.leaves(st0.W))
    sizes = tuple(x.size for x in jax.tree.leaves(st0.W))
    per_client = comm.bits_for(fed.algorithm, d, S.k_for(d, fed.alpha),
                               1, 32, sizes=sizes, alpha=fed.alpha)
    assert float(mets["uplink_bits"]) == \
        float(mets["landed"]) * float(per_client)
    if not landed_victim:
        pick = lambda cs, c: jax.tree.map(lambda x: x[c], cs)
        assert _biteq(pick(st0.client_state, victim),
                      pick(st1.client_state, victim))


def test_buffer_never_applies_below_k():
    """With every update lost, the buffer never reaches K and the server
    NEVER steps: params bitwise frozen, zero bits billed."""
    C = 4
    params, batches, loss_fn = _toy(C)
    fed = _fed(C)
    st0 = fed_init(fed, params)
    churn = ChurnModel(ChurnConfig(drop_prob=1.0), C)
    run = make_async_round(fed, loss_fn, AsyncConfig(buffer_size=2),
                           churn=churn)
    st1, mets = run(st0, batches, rounds=1, max_events=64)
    assert mets["server_steps"] == 0 and mets["landed"] == 0
    assert float(mets["uplink_bits"]) == 0.0
    assert _biteq(st0, st1)
    assert not any(e[1] == "server_step" for e in mets["events"])


def test_buffer_consumed_in_exact_multiples_of_k():
    """Accounting invariant under churn: accepted updates are consumed
    only in batches of exactly K (landed == K * steps + pending), and
    every server_step event carries exactly K staleness entries."""
    C = 6
    params, batches, loss_fn = _toy(C)
    fed = _fed(C)
    cc = ChurnConfig(seed=11, jitter=4, straggler_prob=0.25,
                     drop_prob=0.15)
    K = 4
    run = make_async_round(fed, loss_fn, AsyncConfig(buffer_size=K),
                           churn=ChurnModel(cc, C))
    _, mets = run(fed_init(fed, params), batches, rounds=4)
    assert mets["landed"] == K * mets["server_steps"] \
        + mets["buffer_pending"]
    for e in mets["events"]:
        if e[1] == "server_step":
            assert len(e[3]) == K


# ---------------------------------------------------------------------------
# Staleness weighting (property-checked)
# ---------------------------------------------------------------------------


def test_staleness_scale_is_exactly_one_at_zero():
    """The anchor of the degenerate equivalence: fresh updates must get
    EXACTLY the sync round's weight, for any power."""
    for p in [0.0, 0.25, 0.5, 1.0, 2.0]:
        assert float(staleness_scale(0, p)) == 1.0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=1, max_size=12),
       st.floats(0.0, 3.0))
def test_staleness_weights_properties(stales, power):
    """Nonnegative, normalized, monotone non-increasing in staleness."""
    s = np.asarray(stales)
    w = staleness_weights(s, power)
    assert w.shape == s.shape
    assert np.all(w >= 0)
    assert abs(float(w.sum()) - 1.0) < 1e-12
    order = np.argsort(s, kind="stable")
    ws = w[order]  # increasing staleness => non-increasing weight
    assert np.all(np.diff(ws) <= 1e-15)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 50), st.integers(1, 50), st.floats(0.05, 3.0))
def test_staleness_scale_strictly_penalizes(s, extra, power):
    """With power > 0, a strictly staler update gets strictly less."""
    assert float(staleness_scale(s + extra, power)) \
        < float(staleness_scale(s, power))


# ---------------------------------------------------------------------------
# scan <-> shard_map composition under churn (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

_SUB = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from repro import compat
    from repro.core import FedConfig, fed_init
    from repro.core.async_fed import AsyncConfig, make_async_round
    from repro.data.churn import ChurnConfig, ChurnModel
    from repro.optim import AdamHyper

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4)) * 0.1,
              "b": jnp.zeros((4,))}
    C = 8
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, 16, 8))
    w_true = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    ys = jnp.einsum("cbi,ij->cbj", xs, w_true)

    def loss_fn(p, b):
        x, y = b
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    cc = ChurnConfig(seed=5, jitter=3, straggler_prob=0.25,
                     drop_prob=0.15)
    acfg = AsyncConfig(buffer_size=4, max_staleness=2)

    def go(exec_kind):
        kw = dict(algorithm="fedadam_ssm", alpha=0.3, local_epochs=2,
                  n_clients=C, adam=AdamHyper(lr=0.05),
                  error_feedback=True)
        if exec_kind == "shardmap":
            mesh = jax.make_mesh((8,), ("data",))
            fed = FedConfig(client_mode="vmap", client_axes=("data",),
                            **kw)
            with compat.set_mesh(mesh):
                run = make_async_round(fed, loss_fn, acfg,
                                       churn=ChurnModel(cc, C),
                                       client_exec="shardmap", mesh=mesh)
                return run(fed_init(fed, params), (xs, ys), rounds=4)
        fed = FedConfig(**kw)
        run = make_async_round(fed, loss_fn, acfg,
                               churn=ChurnModel(cc, C))
        return run(fed_init(fed, params), (xs, ys), rounds=4)

    st_s, m_s = go("scan")
    st_m, m_m = go("shardmap")

    def cmp(ta, tb):
        md, eq = 0.0, True
        for a, b in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
            md = max(md, float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))))
            eq = eq and bool(jnp.all(a == b))
        return dict(eq=eq, maxdiff=md)

    out = dict(
        events_eq=(m_s["events"] == m_m["events"]),
        steps=m_s["server_steps"],
        glob=cmp((st_s.W, st_s.M, st_s.V), (st_m.W, st_m.M, st_m.V)),
        cs=cmp(st_s.client_state, st_m.client_state),
        bits_eq=(float(m_s["uplink_bits"]) == float(m_m["uplink_bits"])),
    )
    print("RESULT", json.dumps(out))
""")


@pytest.mark.slow
def test_scan_shardmap_async_equivalence_under_churn():
    """The SAME churn schedule driven through the scan exec and the
    shard_map mesh exec (8 forced host devices, padded cohorts) produces
    the same event log and BIT-identical state — extends the sync
    scan <-> shard_map guarantee of test_fed_equivalence.py to the
    buffered-async driver."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(_REPO / "src")
    out = subprocess.run([sys.executable, "-c", _SUB], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["events_eq"], "schedules diverged between execs"
    assert res["steps"] == 4
    assert res["glob"]["eq"], res
    assert res["cs"]["eq"], res
    assert res["bits_eq"]
