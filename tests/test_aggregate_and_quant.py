"""Aggregation transports + quantizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate as A
from repro.core import quantize as Q
from repro.core import sparsify as S


def _masked(key, C, n, alpha):
    x = jax.random.normal(key, (C, n))
    masks = jnp.stack([S.topk_mask_exact(x[c], S.k_for(n, alpha))
                       for c in range(C)])
    return jnp.where(masks, x, 0.0)


@pytest.mark.parametrize("n", [100, 5000])
@pytest.mark.parametrize("sort_free", [True, False])
def test_sparse_pack_roundtrip(n, sort_free):
    """gather+scatter transport == dense weighted sum on masked deltas."""
    C, alpha = 4, 0.2
    x = _masked(jax.random.PRNGKey(0), C, n, alpha)
    w = jnp.asarray([1.0, 2.0, 0.5, 1.5])
    dense = jnp.tensordot(w, x, axes=(0, 0))
    sparse = A.sparse_independent_gather_sum({"x": x.reshape(C, n)},
                                             alpha, w,
                                             sort_free=sort_free)["x"]
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               atol=1e-5)


def test_shared_pack_uses_w_support():
    """SSM transport: m/v values are gathered at dW's support."""
    C, n, alpha = 2, 64, 0.25
    dw = _masked(jax.random.PRNGKey(1), C, n, alpha)
    dm = jax.random.normal(jax.random.PRNGKey(2), (C, n))
    dv = jax.random.normal(jax.random.PRNGKey(3), (C, n))
    mask = dw != 0
    dm_m, dv_m = jnp.where(mask, dm, 0), jnp.where(mask, dv, 0)
    w = jnp.ones((C,))
    aw, am, av = A.sparse_shared_gather_sum(
        {"x": dw}, {"x": dm_m}, {"x": dv_m}, alpha, w)
    np.testing.assert_allclose(np.asarray(aw["x"]),
                               np.asarray(dw.sum(0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(am["x"]),
                               np.asarray(dm_m.sum(0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(av["x"]),
                               np.asarray(dv_m.sum(0)), atol=1e-5)


def _one_device_agg(alpha, shared=True):
    """make_shardmap_sparse_aggregate on a trivial 1-device client mesh —
    the transport arithmetic (pack, gather, scatter, EF overflow
    feedback) is mesh-size independent, so it unit-tests in-process."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    pspec = {"x": P()}
    agg = A.make_shardmap_sparse_aggregate(mesh, pspec, ("data",), alpha,
                                           shared=shared)
    return agg


def test_shardmap_aggregate_matches_reference_transport():
    """1-client shard_map transport == the jnp gather/scatter reference."""
    C, n, alpha = 1, 128, 0.25
    dw = _masked(jax.random.PRNGKey(7), C, n, alpha)
    dm = jnp.where(dw != 0, jax.random.normal(jax.random.PRNGKey(8),
                                              (C, n)), 0.0)
    w = jnp.ones((C,))
    agg = _one_device_agg(alpha)
    aw, am, av = agg({"x": dw}, {"x": dm}, {"x": dm}, w)
    np.testing.assert_allclose(np.asarray(aw["x"]), np.asarray(dw.sum(0)),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(am["x"]), np.asarray(dm.sum(0)),
                               atol=1e-6)


def test_shardmap_aggregate_ef_overflow_feedback():
    """With per-shard EF state, values the fixed-capacity pack drops from
    the wire are added back into the residual; without overflow the
    residual passes through bit-unchanged."""
    n, alpha = 64, 0.25
    k = S.k_for(n, alpha)
    from repro.kernels.topk_mask.ops import overselect_bound
    kb = min(n, k + overselect_bound(k))           # pack capacity
    assert kb < n // 2
    # MORE nonzeros than capacity: positions 0..2kb-1 hold distinct values
    wf = jnp.zeros((n,)).at[jnp.arange(2 * kb)].set(
        jnp.arange(1.0, 2 * kb + 1))
    dw = wf[None]                                  # (C=1, n)
    err0 = jax.random.normal(jax.random.PRNGKey(9), (1, n))
    w = jnp.ones((1,))
    agg = _one_device_agg(alpha)
    (aw, am, av), err1 = agg({"x": dw}, {"x": dw}, {"x": dw}, w,
                             {"x": err0})
    # kept on the wire: the first kb nonzeros (prefix-sum pack order)
    kept = jnp.zeros((n,)).at[jnp.arange(kb)].set(wf[:kb])
    np.testing.assert_allclose(np.asarray(aw["x"]), np.asarray(kept),
                               atol=1e-6)
    # residual gains exactly the dropped overflow
    np.testing.assert_allclose(np.asarray(err1["x"]),
                               np.asarray(err0 + (wf - kept)[None]),
                               atol=1e-6)

    # no overflow -> residual is returned bitwise unchanged
    few = jnp.zeros((n,)).at[jnp.arange(k // 2)].set(1.0)[None]
    (_, _, _), err2 = agg({"x": few}, {"x": few}, {"x": few}, w,
                          {"x": err0})
    assert bool((err2["x"] == err0).all())


def test_ordered_weighted_sum_matches_dense():
    C, n = 6, 257
    x = jax.random.normal(jax.random.PRNGKey(10), (C, n))
    w = jnp.asarray([1.0, 0.0, 2.0, 0.5, 1.0, 3.0])
    np.testing.assert_allclose(
        np.asarray(A.ordered_weighted_sum({"x": x}, w)["x"]),
        np.asarray(A.dense_weighted_sum({"x": x}, w)["x"]), atol=1e-5)


def test_sign_quant_preserves_block_l1():
    x = jax.random.normal(jax.random.PRNGKey(4), (4096,))
    q = Q.sign_quant(x, block=512)
    # per-block magnitude is the L1 mean: mean |q| == mean |x| per block
    xb = x.reshape(-1, 512)
    qb = np.asarray(q).reshape(-1, 512)
    np.testing.assert_allclose(np.abs(qb).mean(1),
                               np.abs(np.asarray(xb)).mean(1), rtol=1e-5)
    assert set(np.unique(np.sign(qb))) <= {-1.0, 0.0, 1.0}


@pytest.mark.parametrize("bits", [4, 8])
def test_uniform_quant_error_bound(bits):
    x = jax.random.normal(jax.random.PRNGKey(5), (4096,))
    q = Q.uniform_quant(x, bits=bits, block=256)
    qmax = 2.0 ** (bits - 1) - 1
    xb = np.asarray(x).reshape(-1, 256)
    step = np.abs(xb).max(1) / qmax
    err = np.abs(np.asarray(q).reshape(-1, 256) - xb)
    assert (err <= step[:, None] * 0.5 + 1e-6).all()


def test_int8_store_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(6), (1000,)) * 3
    q, scale = Q.int8_store(x, block=128)
    y = Q.int8_load(q, scale, x.shape, x.dtype, block=128)
    rel = float(jnp.max(jnp.abs(y - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01
