"""Aggregation transports + quantizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate as A
from repro.core import quantize as Q
from repro.core import sparsify as S


def _masked(key, C, n, alpha):
    x = jax.random.normal(key, (C, n))
    masks = jnp.stack([S.topk_mask_exact(x[c], S.k_for(n, alpha))
                       for c in range(C)])
    return jnp.where(masks, x, 0.0)


@pytest.mark.parametrize("n", [100, 5000])
@pytest.mark.parametrize("sort_free", [True, False])
def test_sparse_pack_roundtrip(n, sort_free):
    """gather+scatter transport == dense weighted sum on masked deltas."""
    C, alpha = 4, 0.2
    x = _masked(jax.random.PRNGKey(0), C, n, alpha)
    w = jnp.asarray([1.0, 2.0, 0.5, 1.5])
    dense = jnp.tensordot(w, x, axes=(0, 0))
    sparse = A.sparse_independent_gather_sum({"x": x.reshape(C, n)},
                                             alpha, w,
                                             sort_free=sort_free)["x"]
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               atol=1e-5)


def test_shared_pack_uses_w_support():
    """SSM transport: m/v values are gathered at dW's support."""
    C, n, alpha = 2, 64, 0.25
    dw = _masked(jax.random.PRNGKey(1), C, n, alpha)
    dm = jax.random.normal(jax.random.PRNGKey(2), (C, n))
    dv = jax.random.normal(jax.random.PRNGKey(3), (C, n))
    mask = dw != 0
    dm_m, dv_m = jnp.where(mask, dm, 0), jnp.where(mask, dv, 0)
    w = jnp.ones((C,))
    aw, am, av = A.sparse_shared_gather_sum(
        {"x": dw}, {"x": dm_m}, {"x": dv_m}, alpha, w)
    np.testing.assert_allclose(np.asarray(aw["x"]),
                               np.asarray(dw.sum(0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(am["x"]),
                               np.asarray(dm_m.sum(0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(av["x"]),
                               np.asarray(dv_m.sum(0)), atol=1e-5)


def test_sign_quant_preserves_block_l1():
    x = jax.random.normal(jax.random.PRNGKey(4), (4096,))
    q = Q.sign_quant(x, block=512)
    # per-block magnitude is the L1 mean: mean |q| == mean |x| per block
    xb = x.reshape(-1, 512)
    qb = np.asarray(q).reshape(-1, 512)
    np.testing.assert_allclose(np.abs(qb).mean(1),
                               np.abs(np.asarray(xb)).mean(1), rtol=1e-5)
    assert set(np.unique(np.sign(qb))) <= {-1.0, 0.0, 1.0}


@pytest.mark.parametrize("bits", [4, 8])
def test_uniform_quant_error_bound(bits):
    x = jax.random.normal(jax.random.PRNGKey(5), (4096,))
    q = Q.uniform_quant(x, bits=bits, block=256)
    qmax = 2.0 ** (bits - 1) - 1
    xb = np.asarray(x).reshape(-1, 256)
    step = np.abs(xb).max(1) / qmax
    err = np.abs(np.asarray(q).reshape(-1, 256) - xb)
    assert (err <= step[:, None] * 0.5 + 1e-6).all()


def test_int8_store_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(6), (1000,)) * 3
    q, scale = Q.int8_store(x, block=128)
    y = Q.int8_load(q, scale, x.shape, x.dtype, block=128)
    rel = float(jnp.max(jnp.abs(y - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01
