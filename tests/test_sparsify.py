"""Property tests for the Top_k sparsifier (Definitions 1-2 of the paper)."""
import jax
import jax.numpy as jnp
import numpy as np

# real hypothesis when installed (CI), deterministic seeded fallback
# otherwise — the property tests run everywhere, never skipped
from _propcheck import given, settings, st

from repro.core import sparsify as S


@st.composite
def _vec(draw, min_n=4, max_n=4096):
    n = draw(st.integers(min_n, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    scale = draw(st.sampled_from([1e-6, 1.0, 1e4]))
    return jnp.asarray(rng.normal(0, scale, size=n).astype(np.float32))


@settings(max_examples=40, deadline=None)
@given(_vec(), st.floats(0.01, 1.0))
def test_k_contraction_property(x, alpha):
    """Definition 2: E||x - Top_k(x)||^2 <= (1 - k/d)||x||^2.
    Top-k is the *best* k-contraction, so this holds deterministically."""
    k = S.k_for(x.size, alpha)
    mask = S.topk_mask_exact(x, k)
    err = jnp.sum(jnp.where(mask, 0.0, x) ** 2)
    bound = (1.0 - k / x.size) * jnp.sum(x ** 2)
    assert float(err) <= float(bound) + 1e-6 * float(jnp.sum(x ** 2)) + 1e-30


@settings(max_examples=40, deadline=None)
@given(_vec(), st.floats(0.01, 0.9))
def test_exact_mask_count_and_magnitudes(x, alpha):
    k = S.k_for(x.size, alpha)
    mask = S.topk_mask_exact(x, k)
    assert int(mask.sum()) == k
    kept_min = jnp.min(jnp.where(mask, jnp.abs(x), jnp.inf))
    dropped_max = jnp.max(jnp.where(mask, -jnp.inf, jnp.abs(x)))
    assert float(kept_min) >= float(dropped_max) - 1e-7


@settings(max_examples=25, deadline=None)
@given(_vec(min_n=64), st.floats(0.02, 0.5))
def test_threshold_mask_superset_semantics(x, alpha):
    """Threshold mask keeps >= k elements and every kept element is >=
    every dropped element in |.| (it's a level set of |x|)."""
    k = S.k_for(x.size, alpha)
    mask = S.topk_mask_threshold(x, k)
    assert int(mask.sum()) >= min(k, x.size)
    kept_min = jnp.min(jnp.where(mask, jnp.abs(x), jnp.inf))
    dropped_max = jnp.max(jnp.where(mask, -jnp.inf, jnp.abs(x)))
    assert float(kept_min) >= float(dropped_max) - 1e-7


def test_blocked_mask_fraction():
    x = jax.random.normal(jax.random.PRNGKey(0), (3 * S.BLOCK + 123,))
    m = S.blocked_topk_mask(x, 0.05)
    frac = float(m.mean())
    # per-block exact alpha, inflated only by the padded tail block
    assert 0.05 <= frac <= 0.05 * (1 + S.BLOCK / x.size) + 1e-3


def test_sparsify_identity_at_alpha_1():
    x = jax.random.normal(jax.random.PRNGKey(1), (300,))
    mask = S.topk_mask_exact(x, 300)
    assert bool(jnp.all(S.sparsify(x, mask) == x))


def test_tree_masks_per_tensor_and_global():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (100,)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (50, 4)) * 10}
    mt = S.tree_topk_masks(jax.tree.map(jnp.abs, tree), 0.1,
                           scope="per_tensor")
    assert int(mt["a"].sum()) == 10 and int(mt["b"].sum()) == 20
    mg = S.tree_topk_masks(jax.tree.map(jnp.abs, tree), 0.1, scope="global")
    # global ranking: 'b' is 10x larger so it should dominate the budget
    assert int(mg["a"].sum()) + int(mg["b"].sum()) == 30
    assert int(mg["b"].sum()) > int(mg["a"].sum())


def test_sparsity_error_norm():
    x = jnp.asarray([3.0, -4.0, 0.1, -0.2])
    mask = S.topk_mask_exact(x, 2)
    err = S.tree_sparsity_error({"x": x}, {"x": mask})
    np.testing.assert_allclose(float(err), np.sqrt(0.1**2 + 0.2**2),
                               rtol=1e-6)
