"""Roofline machinery: HLO collective parsing, trip scaling, term math."""
import jax.numpy as jnp

from repro import roofline as RL

_HLO = """
HloModule jit_step
  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%x), metadata={op_name="jit(step)/foo" stack_frame_id=1}
  %all-gather.2 = bf16[2,512]{1,0} all-gather(%y), metadata={op_name="jit(step)/while/body/bar" stack_frame_id=2}
  %all-reduce.3 = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), metadata={op_name="jit(step)/while/body/closed_call/while/body/baz"}
  %fusion.9 = f32[4]{0} fusion(%c), kind=kLoop
  %wrapped_all_reduce_fusion = ...
"""


def test_collective_parse_and_trip_scaling():
    out = RL.collective_bytes(_HLO, loop_trips=(3, 5))
    # depth 0: 16*1024*4 = 65536 ; depth 1: 2*512*2 = 2048 * 3
    # depth 2: 2*8*4 = 64 * 15
    assert out["all-reduce"] == 65536 + 64 * 15
    assert out["all-gather"] == 2048 * 3
    assert out["total_static"] == 65536 + 2048 + 64
    assert out["count"] == 3


def test_roofline_terms_and_bottleneck():
    rl = RL.Roofline(arch="a", shape="s", mesh="m", chips=256,
                     hlo_flops=256 * RL.PEAK_FLOPS,        # 1 s compute
                     hlo_bytes=256 * RL.HBM_BW * 2,        # 2 s memory
                     coll_bytes=256 * RL.ICI_LINKS * RL.ICI_BW * 0.5,
                     model_flops=128 * RL.PEAK_FLOPS)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 2.0) < 1e-9
    assert abs(rl.t_collective - 0.5) < 1e-9
    assert rl.bottleneck == "memory"
    assert abs(rl.useful_ratio - 0.5) < 1e-9


def test_analytic_model_flops():
    from repro.configs import get_config
    cfg = get_config("starcoder2-3b")
    train = RL.analytic_model_flops(cfg, "train", 4096, 256, local_epochs=2)
    decode = RL.analytic_model_flops(cfg, "decode", 32768, 128)
    n = cfg.active_param_count()
    assert train == 6.0 * n * 4096 * 256 * 2
    assert decode == 2.0 * n * 128
