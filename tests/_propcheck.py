"""Property-testing front door for this suite.

When ``hypothesis`` is installed (CI installs it; see
.github/workflows/ci.yml) the real library is re-exported unchanged —
full shrinking, the works.  When it is not (the pinned repro container
ships without it), a small deterministic fallback implements exactly
the strategy subset this suite uses — ``integers``, ``floats``,
``sampled_from``, ``lists``, ``composite`` — drawing examples from a
seeded per-test ``numpy`` RNG.  No shrinking, but every run draws the
same examples and a failure reports its example index, so it replays.

Either way ``pytest`` sees plain passing/failing tests: the property
suite runs everywhere instead of being importorskip'd away.

Usage (identical under both backends)::

    from _propcheck import HAVE_HYPOTHESIS, given, settings, st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10), st.floats(0.0, 1.0))
    def test_something(n, x): ...
"""
from __future__ import annotations

import functools
import zlib

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback, no new deps
    HAVE_HYPOTHESIS = False
    import numpy as _np

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        """A strategy is just ``rng -> value``."""

        def __init__(self, fn):
            self._fn = fn

        def draw(self, rng):
            return self._fn(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(
                lambda rng: elems[int(rng.integers(0, len(elems)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def _draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(_draw)

        @staticmethod
        def composite(fn):
            """``fn(draw, *args)`` -> strategy factory, like hypothesis:
            the wrapped function's first arg is a ``draw`` callable."""
            @functools.wraps(fn)
            def factory(*args, **kwargs):
                def _draw(rng):
                    return fn(lambda strat: strat.draw(rng),
                              *args, **kwargs)
                return _Strategy(_draw)
            return factory

    st = _Strategies()

    class settings:  # noqa: N801 - mirrors the hypothesis name
        """Only ``max_examples`` is honoured; ``deadline`` etc. are
        accepted and ignored (the fallback has no shrinker/timer)."""

        def __init__(self, max_examples=_DEFAULT_EXAMPLES, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._propcheck_max_examples = self.max_examples
            return fn

    def given(*strategies):
        """Run the test once per drawn example.  The RNG seed is derived
        from the test's name, so the example stream is a pure function
        of the code — rerunning a red test replays the same failure."""
        def decorate(fn):
            # NOT functools.wraps: __wrapped__ would make pytest see the
            # original (x, alpha, ...) signature and hunt for fixtures
            def runner():
                n = getattr(runner, "_propcheck_max_examples",
                            _DEFAULT_EXAMPLES)
                seed = zlib.crc32(fn.__name__.encode())
                for i in range(n):
                    rng = _np.random.default_rng([seed, i])
                    args = [s.draw(rng) for s in strategies]
                    try:
                        fn(*args)
                    except AssertionError as e:
                        raise AssertionError(
                            "falsifying example %d/%d of %s (seeded "
                            "fallback, args=%r)" % (i, n, fn.__name__,
                                                    args)) from e
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return decorate
