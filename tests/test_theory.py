"""Theorem-1/2/3 calculators: Proposition-1 ordering, bound behaviour under
the paper's parameter sweeps (Figs. 3-5 trends)."""
import math

import pytest

from repro.core import theory as T


def _p(**kw):
    # regime where the bound arithmetic stays finite: the paper's constants
    # are astronomically loose at practical (eta, eps, d) — psi contains
    # (1-beta2) d G^2 / eps which overflows r_plus^l for d ~ 1e7, eps=1e-6.
    # We evaluate at d=1e6, eps=1e-2, small eta (noted in EXPERIMENTS.md).
    base = dict(d=1_000_000, G=1.0, rho=1.0, sigma_l=0.5, sigma_g=0.5,
                eta=1e-12, beta1=0.9, beta2=0.999, eps=1e-2, D_n=32)
    base.update(kw)
    return T.BoundParams(**base)


def test_proposition1_condition_holds_at_scale():
    """beta2 = 0.999 < 1 - 1/(1 + 2 G rho sqrt(d)) for large d (Remark 3:
    the condition is near-vacuous at scale) — and FAILS for small d,
    confirming it is a genuine large-d statement."""
    assert T.proposition1_condition(_p())
    assert not T.proposition1_condition(_p(d=1000))


def test_proposition1_ordering():
    """Gamma > Theta > Lambda (Eq. 27) under condition (26)."""
    p = _p()
    for l in (1, 2, 5):
        assert T.proposition1_holds(p, l), l


def test_gamma_dominates_justifies_ssm_w():
    """The SSM=Top_k(|dW|) rule: Gamma >> Lambda means dW's sparsification
    error carries the largest weight in the Theorem-1 bound."""
    p = _p()
    assert T.gamma(p, 3) > 10 * T.lam(p, 3)


def test_divergence_bound_monotone_in_errors():
    p = _p()
    b1 = T.divergence_bound(p, 2, 1.0, 1.0, 1.0)
    b2 = T.divergence_bound(p, 2, 2.0, 1.0, 1.0)
    assert b2 > b1 > 0


def test_theorem2_decreases_with_alpha():
    """Fig. 5 trend: larger sparsification ratio (less sparsification)
    improves the bound."""
    p = _p(eta=1e-4)
    bounds = [T.theorem2_bound(p, a, L=5, T=100, f0_minus_fT=1.0)
              for a in (0.01, 0.05, 0.5, 1.0)]
    assert all(x >= y - 1e-9 for x, y in zip(bounds, bounds[1:])), bounds


def test_theorem3_rate_improves_with_T():
    """With the Proposition-3 lr schedule eta = O(ln T / (L^2 T)) the bound
    is non-increasing in T and its optimization term (1-eta*mu)^T * f0
    vanishes.  (The bound's CONSTANT terms dominate numerically — the
    paper's looseness, recorded in EXPERIMENTS.md — so we assert the
    T-dependent structure, not a large absolute drop.)"""
    import math
    L, mu = 3, 0.5

    def bound(Tr):
        eta = math.log(Tr) / (L ** 2 * Tr)
        return T.theorem3_bound(_p(eta=eta), 0.05, L=L, T=Tr, mu=mu,
                                f0_minus_fstar=1.0)

    b10, b1k, b100k = bound(10), bound(1000), bound(100000)
    assert b10 >= b1k >= b100k
    # the optimization term itself vanishes
    eta10 = math.log(10) / (L ** 2 * 10)
    eta100k = math.log(100000) / (L ** 2 * 100000)
    assert (1 - eta100k * mu) ** 100000 < (1 - eta10 * mu) ** 10


def test_optimal_local_epoch_crossover():
    """Remark 6: L* grows as T shrinks and as alpha shrinks."""
    p = _p()
    l_small_T = T.optimal_local_epochs(p, 0.05, T=10, f0_minus_fT=1.0)
    l_big_T = T.optimal_local_epochs(p, 0.05, T=10_000, f0_minus_fT=1.0)
    assert l_small_T > l_big_T
    l_sparse = T.optimal_local_epochs(p, 0.01, T=100, f0_minus_fT=1.0)
    l_dense = T.optimal_local_epochs(p, 0.9, T=100, f0_minus_fT=1.0)
    assert l_sparse > l_dense


def test_phi_floor_positive():
    """Phi (Eq. 20) — the heterogeneity floor — is positive and grows with
    the global variance sigma_g (Remark 1)."""
    lo = T.phi_const(_p(sigma_g=0.1), 2)
    hi = T.phi_const(_p(sigma_g=1.0), 2)
    assert 0 < lo < hi
