"""Known-bad pallas fixture: a misaligned BlockSpec tile and a
VMEM-budget blowout in one pallas_call each."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)
HUGE = (4096, 4096)


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def misaligned(x):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec(BLOCK, lambda i: (i, 0))],
        out_specs=pl.BlockSpec((3, 100), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((3, 100), jnp.float32),
    )(x)


def vmem_hog(x):
    spec = pl.BlockSpec(HUGE, lambda i: (0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(HUGE, jnp.float32),
    )(x)
