"""Known-bad bits-accounting fixture: a registered compressor without a
real bits_per_client, doc-table drift in both directions, a compress
that ships no wire payload, and an off-contract quantizer block."""


def register(name):
    def deco(factory):
        return factory
    return deco


class Compressor:
    def bits_per_client(self, d):
        raise NotImplementedError


class NoBitsCompressor(Compressor):
    """Defines nothing: inherits only the pure-raise protocol stub."""

    def compress(self, deltas, state):
        return deltas, state, self.pack_wire(deltas), 0


@register("no_bits")
def _no_bits_factory(fed):
    return NoBitsCompressor()


class FineCompressor(Compressor):
    def bits_per_client(self, d):
        return 32 * d


@register("undocumented")
def _fine_factory(fed):
    return FineCompressor()


class NoWireCompressor(Compressor):
    """Real bits formula, but compress never builds a WirePayload —
    the reported bits have no transported bytes behind them."""

    def bits_per_client(self, d):
        return d

    def compress(self, deltas, state):
        return deltas, state, 0


@register("no_wire")
def _no_wire_factory(fed):
    return NoWireCompressor()


class OddBlockCompressor(Compressor):
    """block=512 disagrees with wire.SCALE_BLOCK: the payload's
    per-1024-element scale stream would misalign with the quantizer."""

    block = 512

    def bits_per_client(self, d):
        return d + 32 * (d // self.block)

    def compress(self, deltas, state):
        payload = wire.pack_sign(deltas)  # noqa: F821 (AST-only fixture)
        return deltas, state, payload, 0


@register("odd_block")
def _odd_block_factory(fed):
    return OddBlockCompressor()
