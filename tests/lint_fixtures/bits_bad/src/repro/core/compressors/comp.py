"""Known-bad bits-accounting fixture: a registered compressor without a
real bits_per_client, plus doc-table drift in both directions."""


def register(name):
    def deco(factory):
        return factory
    return deco


class Compressor:
    def bits_per_client(self, d):
        raise NotImplementedError


class NoBitsCompressor(Compressor):
    """Defines nothing: inherits only the pure-raise protocol stub."""

    def compress(self, deltas, state):
        return deltas, state, 0


@register("no_bits")
def _no_bits_factory(fed):
    return NoBitsCompressor()


class FineCompressor(Compressor):
    def bits_per_client(self, d):
        return 32 * d


@register("undocumented")
def _fine_factory(fed):
    return FineCompressor()
