"""Known-bad ref-parity fixture: an op with no oracle and no test."""
import jax.numpy as jnp


def orphan_kernel(x):
    return jnp.abs(x)


def tested_only(x):
    return jnp.sign(x)
