"""Oracle module that covers neither op."""
import jax.numpy as jnp


def unrelated_ref(x):
    return x
