"""References tested_only in code; mentions orphan_kernel in a docstring
only (must NOT count as a reference)."""
from repro.kernels.demo.ops import tested_only


def test_tested_only():
    """orphan_kernel is named here but never exercised."""
    assert tested_only is not None
