"""Known-good bits-accounting fixture: registry, bits, wire payloads,
block literals, and docs agree."""


def register(name):
    def deco(factory):
        return factory
    return deco


class Compressor:
    def bits_per_client(self, d):
        raise NotImplementedError


class _Base(Compressor):
    block = 1024

    def bits_per_client(self, d):
        return 32 * d


class DenseLike(_Base):
    def compress(self, deltas, state):
        payload = self.pack_wire(deltas)
        return deltas, state, payload, 0


@register("dense_like")
def _factory(fed):
    return DenseLike()


register("dense_alias")(_factory)
