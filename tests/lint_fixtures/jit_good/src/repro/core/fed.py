"""Known-good jit fixture: all host math provably static."""
import jax.numpy as jnp

LANES = 128


def make_round(cfg):
    n_active = max(1, int(round(0.5 * 8)))    # build-time, not traced

    def round_fn(state, batch):
        n = state.shape[0]
        pad = int(-n % LANES)                 # shape math: static
        if state.ndim > 2:                    # shape test: static
            state = state.reshape(n, -1)
        return jnp.pad(state, (0, pad)) * n_active

    return round_fn
