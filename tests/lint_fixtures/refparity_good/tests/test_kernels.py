from repro.kernels.demo.ops import scale_kernel
from repro.kernels.demo.ref import scale_ref


def test_scale_parity():
    assert scale_kernel(1.0) == scale_ref(1.0)
