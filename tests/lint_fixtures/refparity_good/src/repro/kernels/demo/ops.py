"""Known-good ref-parity fixture: op + oracle + test reference."""
import jax.numpy as jnp


def scale_kernel(x):
    return jnp.abs(x) * 2.0


def _helper(x):
    return x  # private: exempt


def plain_constant(k):
    return k + 1  # no jax/jnp: a contract constant, exempt
