import jax.numpy as jnp


def scale_ref(x):
    return jnp.abs(x) * 2.0
