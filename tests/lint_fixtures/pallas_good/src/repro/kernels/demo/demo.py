"""Known-good pallas fixture: aligned tiles, tiny VMEM footprint."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double(x):
    spec = pl.BlockSpec(BLOCK, lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=(x.shape[0] // SUBLANES,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)
