"""Known-bad jit fixture: every hazard class inside a traced round."""
import jax.numpy as jnp


def make_round(cfg):
    def round_fn(state, thresh):
        k = int(thresh * 10)              # host cast on a traced param
        s = state.sum().item()            # device sync
        import numpy as np
        arr = np.asarray(state)           # host transfer
        if thresh > 0.5:                  # data-dependent control flow
            state = state * 2.0
        return state + k + s + arr.sum()

    return round_fn
