"""Mesh integration tests: run in a SUBPROCESS with 8 forced host devices
(this process must keep the 1-device backend for the smoke tests).

Covers: sharded FL train step executes and matches the unsharded result;
serve step executes sharded; the shard_map sparse transport engages the
expected collectives.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

_REPO = Path(__file__).resolve().parents[1]

# The sharded step builders target the jax >= 0.6 top-level API
# (jax.shard_map / jax.set_mesh) THROUGH repro.compat, which falls back
# to jax.experimental.shard_map + a Mesh-context stand-in on older jax
# (the pinned 0.4.37 container) — so these tests run on both.


def _run_sub(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(_REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config, reduce_for_smoke
        from repro.launch import steps as ST
        from repro.launch.mesh import make_test_mesh
        from repro.models import init_params
        from repro.core import fed_init

        cfg = reduce_for_smoke(get_config("starcoder2-3b"))
        ST.SHAPES["train_4k"] = ST.ShapeSpec("train_4k", 64, 4, "train")
        mesh = make_test_mesh()
        bundle = ST.build_step(cfg, mesh, "train_4k", local_epochs=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        fed = bundle.static["fed"]
        state = fed_init(fed, params)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1),
            bundle.args_sds[1]["tokens"].shape, 0, cfg.vocab_size)}
        with compat.set_mesh(mesh):
            jfn = compat.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings)
            st2, mets = jfn(state, batch)
        loss = float(jnp.mean(mets["loss"]))
        wsum = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                     for x in jax.tree.leaves(st2.W)))
        print("RESULT", json.dumps({"loss": loss, "wsum": wsum}))
    """)
    res = _run_sub(code)
    assert res["loss"] > 0 and res["wsum"] > 0
    import math
    assert math.isfinite(res["loss"]) and math.isfinite(res["wsum"])


@pytest.mark.slow
def test_sharded_serve_step_runs():
    code = textwrap.dedent("""
        import json, functools, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config, reduce_for_smoke
        from repro.launch import steps as ST
        from repro.launch.mesh import make_test_mesh
        from repro.models import init_params, materialize, cache_meta

        cfg = reduce_for_smoke(get_config("mamba2-1-3b"))
        ST.SHAPES["decode_32k"] = ST.ShapeSpec("decode_32k", 128, 4, "decode")
        mesh = make_test_mesh()
        bundle = ST.build_step(cfg, mesh, "decode_32k")
        params = init_params(cfg, jax.random.PRNGKey(0))
        caches = materialize(cache_meta(cfg, 4, 128), jax.random.PRNGKey(1))
        tok = jnp.zeros((4,), jnp.int32)
        with compat.set_mesh(mesh):
            jfn = compat.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings)
            logits, caches = jfn(params, caches, jnp.int32(0), tok)
            logits, _ = jfn(params, caches, jnp.int32(1), tok)
        ok = bool(jnp.isfinite(logits).all())
        print("RESULT", json.dumps({"ok": ok,
                                    "shape": list(logits.shape)}))
    """)
    res = _run_sub(code)
    assert res["ok"] and res["shape"][0] == 4


@pytest.mark.slow
def test_sharded_train_step_threads_ef_state():
    """Stateful (error-feedback) compressor through the full launch path:
    per-client EF residuals enter the shard_map MANUAL region sharded
    over the client mesh axes, are updated by the round, and come back
    client-stacked — nonzero after a round that dropped anything."""
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config, reduce_for_smoke
        from repro.launch import steps as ST
        from repro.launch.mesh import make_test_mesh
        from repro.models import init_params
        from repro.core import fed_init

        cfg = reduce_for_smoke(get_config("starcoder2-3b"))
        ST.SHAPES["train_4k"] = ST.ShapeSpec("train_4k", 64, 4, "train")
        mesh = make_test_mesh()
        bundle = ST.build_step(cfg, mesh, "train_4k", local_epochs=2,
                               aggregate="sparse_gather",
                               error_feedback=True, alpha=0.05)
        fed = bundle.static["fed"]
        assert fed.error_feedback and fed.aggregate == "sparse_gather"
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = fed_init(fed, params)
        assert state.client_state is not None, "EF state missing at init"
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1),
            bundle.args_sds[1]["tokens"].shape, 0, cfg.vocab_size)}
        with compat.set_mesh(mesh):
            jfn = compat.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings)
            st2, mets = jfn(state, batch)
            st3, _ = jfn(st2, batch)
        err1 = st2.client_state["comp"]["err"]
        err2 = st3.client_state["comp"]["err"]
        n_c = fed.n_clients
        lead_ok = all(x.shape[0] == n_c for x in jax.tree.leaves(err1))
        norm1 = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(err1)))
        norm2 = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(err2)))
        carried = any(bool(jnp.any(a != b)) for a, b in
                      zip(jax.tree.leaves(err1), jax.tree.leaves(err2)))
        loss = float(jnp.mean(mets["loss"]))
        print("RESULT", json.dumps({
            "loss": loss, "lead_ok": lead_ok, "carried": carried,
            "err_norm1": norm1, "err_norm2": norm2}))
    """)
    res = _run_sub(code)
    import math
    assert math.isfinite(res["loss"]) and res["loss"] > 0
    assert res["lead_ok"], "EF state lost its client axis"
    # a sparse round drops mass, so the residual must be populated and
    # must evolve round-over-round (it is carried, not re-zeroed)
    assert res["err_norm1"] > 0 and math.isfinite(res["err_norm1"])
    assert res["err_norm2"] > 0 and math.isfinite(res["err_norm2"])
    assert res["carried"]


@pytest.mark.slow
def test_sparse_transport_collectives_present():
    """The shard_map sparse aggregation lowers to all-gathers whose total
    bytes are far below the dense all-reduce of the model."""
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config, reduce_for_smoke
        from repro.launch import steps as ST
        from repro.launch.mesh import make_test_mesh
        from repro import roofline as RL

        cfg = reduce_for_smoke(get_config("starcoder2-3b"))
        ST.SHAPES["train_4k"] = ST.ShapeSpec("train_4k", 64, 4, "train")
        mesh = make_test_mesh()
        out = {}
        for algo, agg in [("fedadam_ssm", "sparse_gather"),
                          ("fedadam", "dense")]:
            bundle = ST.build_step(cfg, mesh, "train_4k",
                                   algorithm=algo, aggregate=agg,
                                   local_epochs=1, alpha=0.05)
            with compat.set_mesh(mesh):
                jfn = compat.jit(bundle.fn, in_shardings=bundle.in_shardings,
                                 out_shardings=bundle.out_shardings)
                compiled = jfn.lower(*bundle.args_sds).compile()
            coll = RL.collective_bytes(compiled.as_text(),
                                       bundle.static["loop_trips"])
            out[algo] = coll["total"]
        print("RESULT", json.dumps(out))
    """)
    res = _run_sub(code)
    assert res["fedadam_ssm"] > 0
    assert res["fedadam_ssm"] < 0.6 * res["fedadam"], res
