"""Compressor subsystem: registry round-trips, exact bit accounting,
error-feedback state across rounds, and drop-in registration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, masks
from repro.core import sparsify as S
from repro.core.compressors import (
    DIAG_KEYS,
    Compressor,
    Deltas,
    Packed,
    available,
    diag_metrics,
    make_compressor,
    register,
    transport_of,
    unregister,
)
from repro.core.fed import ALGORITHMS, FedConfig, fed_init, make_fl_round
from repro.optim import AdamHyper


def _tree(seed, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {"w": jax.random.normal(ks[0], (32, 8)) * scale,
            "b": jax.random.normal(ks[1], (8,)) * scale}


def _deltas(seed=1):
    return Deltas(_tree(seed), _tree(seed + 100, 0.1), _tree(seed + 200, 0.01))


def _fed(algo, **kw):
    kw.setdefault("alpha", 0.25)
    kw.setdefault("n_clients", 4)
    return FedConfig(algorithm=algo, **kw)


# ---------------------------------------------------------------------------
# Registry + round-trip
# ---------------------------------------------------------------------------


def test_registry_covers_all_algorithms_in_order():
    assert tuple(available()) == tuple(ALGORITHMS)


def test_unknown_algorithm_raises():
    class Cfg:
        algorithm = "nope"
    with pytest.raises(KeyError, match="nope"):
        make_compressor(Cfg())


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_roundtrip_structure_and_finiteness(algo):
    comp = make_compressor(_fed(algo))
    deltas = _deltas()
    state = comp.init_state(deltas.W)
    packed, new_state, bits = comp.compress(deltas, state)
    rec = comp.decompress(packed)
    # reconstruction has the input's tree structure and is finite
    assert (jax.tree.structure((rec.W, rec.M, rec.V))
            == jax.tree.structure((deltas.W, deltas.M, deltas.V)))
    for a, b in zip(jax.tree.leaves((rec.W, rec.M, rec.V)),
                    jax.tree.leaves((deltas.W, deltas.M, deltas.V))):
        assert a.shape == b.shape
        assert np.isfinite(np.asarray(a)).all()
    # diagnostics carry the canonical keys
    assert set(packed.diag) == set(DIAG_KEYS)
    # stateful compressors return the same state structure
    assert (state is None) == (new_state is None)
    if state is not None:
        assert (jax.tree.structure(state) == jax.tree.structure(new_state))
    d = sum(x.size for x in jax.tree.leaves(deltas.W))
    assert bits == comp.bits_per_client(d)


@pytest.mark.parametrize("algo", ["fedadam", "fedsgd"])
def test_dense_compressor_is_identity(algo):
    comp = make_compressor(_fed(algo))
    deltas = _deltas()
    packed, _, _ = comp.compress(deltas, None)
    rec = comp.decompress(packed)
    for a, b in zip(jax.tree.leaves(tuple(rec)),
                    jax.tree.leaves(tuple(Deltas(*deltas)))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ssm_compress_matches_direct_shared_mask():
    """The compressor reproduces Eq. 28 exactly: mask = Top_k(|dW|),
    applied to all three tensors."""
    alpha = 0.3
    comp = make_compressor(_fed("fedadam_ssm", alpha=alpha))
    deltas = _deltas()
    packed, _, _ = comp.compress(deltas, None)
    mask = masks.shared_mask("ssm_w", deltas.W, deltas.M, deltas.V, alpha)
    for got, want in zip(
            jax.tree.leaves((packed.W, packed.M, packed.V)),
            jax.tree.leaves((S.tree_sparsify(deltas.W, mask),
                             S.tree_sparsify(deltas.M, mask),
                             S.tree_sparsify(deltas.V, mask)))):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shared_vs_independent_support():
    """SSM: one support for W/M/V.  Top: supports may differ."""
    comp = make_compressor(_fed("fedadam_ssm"))
    packed, _, _ = comp.compress(_deltas(), None)
    for w, m, v in zip(jax.tree.leaves(packed.W), jax.tree.leaves(packed.M),
                       jax.tree.leaves(packed.V)):
        assert bool(jnp.all((w != 0) == (m != 0)) &
                    jnp.all((w != 0) == (v != 0)))
    assert transport_of("fedadam_ssm") == "shared_sparse"
    assert transport_of("fedadam_top") == "independent_sparse"
    assert transport_of("fedadam") == "dense"
    assert transport_of("efficient_adam") == "quantized"


# ---------------------------------------------------------------------------
# Bit accounting: compressor reports == core/comm.py formulas, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("d", [1000, 1 << 20, 12_345_678])
def test_bits_match_comm_formulas_exactly(algo, d):
    fed = _fed(algo, alpha=0.05, n_clients=7, quant_bits=4)
    comp = make_compressor(fed)
    k = S.k_for(d, fed.alpha)
    want = comm.bits_for(algo, d, k, fed.n_clients, fed.q_bits,
                         quant_bits=fed.quant_bits)
    assert fed.n_clients * comp.bits_per_client(d) == want


def test_compress_reports_the_same_bits_as_the_round_metric():
    fed = _fed("fedadam_ssm", alpha=0.1)
    comp = make_compressor(fed)
    deltas = _deltas()
    d = sum(x.size for x in jax.tree.leaves(deltas.W))
    _, _, bits = comp.compress(deltas, None)
    assert bits == comm.bits_for("fedadam_ssm", d, S.k_for(d, fed.alpha),
                                 1, fed.q_bits)


# ---------------------------------------------------------------------------
# Error feedback across rounds, scan AND vmap
# ---------------------------------------------------------------------------


def _toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4)) * 0.1,
              "b": jnp.zeros((4,))}
    C = 4
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, 16, 8))
    w_true = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    ys = jnp.einsum("cbi,ij->cbj", xs, w_true)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    return params, (xs, ys), loss_fn, C


def _run_rounds(algo, mode, rounds=3, **kw):
    params, batches, loss_fn, C = _toy()
    fed = FedConfig(algorithm=algo, alpha=0.25, local_epochs=2, n_clients=C,
                    adam=AdamHyper(lr=0.05), client_mode=mode, **kw)
    rf = jax.jit(make_fl_round(fed, loss_fn))
    st = fed_init(fed, params)
    errs = []
    for _ in range(rounds):
        st, mets = rf(st, batches)
        errs.append(jax.tree.map(np.asarray, st.client_state["comp"]["err"]))
    return st, errs, mets


@pytest.mark.parametrize("mode", ["scan", "vmap"])
@pytest.mark.parametrize("algo", ["onebit_adam", "efficient_adam"])
def test_error_feedback_residuals_carried_across_rounds(algo, mode):
    st, errs, mets = _run_rounds(algo, mode)
    # residual exists per client, is nonzero after round 1, and evolves
    lead = jax.tree.leaves(errs[0])[0].shape[0]
    assert lead == 4
    assert max(np.abs(l).max() for l in jax.tree.leaves(errs[0])) > 0
    moved = max(np.abs(a - b).max()
                for a, b in zip(jax.tree.leaves(errs[0]),
                                jax.tree.leaves(errs[1])))
    assert moved > 0
    for leaf in jax.tree.leaves(errs[-1]):
        assert np.isfinite(leaf).all()
    assert np.isfinite(float(jnp.mean(mets["loss"])))


@pytest.mark.parametrize("algo", ["onebit_adam", "efficient_adam"])
def test_error_feedback_scan_equals_vmap(algo):
    st_s, errs_s, _ = _run_rounds(algo, "scan")
    st_v, errs_v, _ = _run_rounds(algo, "vmap")
    for a, b in zip(jax.tree.leaves(st_s.W), jax.tree.leaves(st_v.W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree.leaves(errs_s[-1]), jax.tree.leaves(errs_v[-1])):
        np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("mode", ["scan", "vmap"])
def test_sparse_error_feedback_state_lives_under_comp(mode):
    st, errs, _ = _run_rounds("fedadam_ssm", mode, error_feedback=True)
    assert set(st.client_state) == {"comp"}
    assert max(np.abs(l).max() for l in jax.tree.leaves(errs[0])) > 0


def test_efficient_adam_keeps_persistent_local_moments():
    st, _, _ = _run_rounds("efficient_adam", "scan")
    assert set(st.client_state) == {"comp", "m", "v"}
    # local moments actually trained (nonzero, per-client leading axis)
    m0 = jax.tree.leaves(st.client_state["m"])[0]
    assert m0.shape[0] == 4 and float(jnp.abs(m0).max()) > 0


# ---------------------------------------------------------------------------
# Drop-in registration: a new scheme is one registration away
# ---------------------------------------------------------------------------


def test_custom_compressor_dropin_runs_a_round():
    @dataclasses.dataclass(frozen=True)
    class SignW(Compressor):
        """FedLion-flavoured toy: sign-compress dW, drop moments."""
        name: str = "sign_w"
        q_bits: int = 32
        server_update = "w_only"

        def compress(self, deltas, state):
            from repro.core import quantize
            q = quantize.tree_sign_quant(deltas.W)
            z = jax.tree.map(jnp.zeros_like, deltas.M)
            packed = Packed(q, z, jax.tree.map(jnp.zeros_like, deltas.V),
                            diag_metrics(deltas, Deltas(q, z, z)))
            d = sum(x.size for x in jax.tree.leaves(deltas.W))
            return packed, state, self.bits_per_client(d)

        def bits_per_client(self, d):
            import math
            return d + self.q_bits * math.ceil(d / 1024)

    register("sign_w")(lambda fed: SignW(q_bits=fed.q_bits))
    try:
        assert "sign_w" in available()
        params, batches, loss_fn, C = _toy()
        fed = FedConfig(algorithm="sign_w", local_epochs=2, n_clients=C,
                        adam=AdamHyper(lr=0.05))
        rf = jax.jit(make_fl_round(fed, loss_fn))
        st = fed_init(fed, params)
        losses = []
        for _ in range(8):
            st, mets = rf(st, batches)
            losses.append(float(jnp.mean(mets["loss"])))
        assert np.isfinite(losses).all() and losses[-1] < losses[0]
        assert float(mets["uplink_bits"]) == C * SignW().bits_per_client(
            sum(x.size for x in jax.tree.leaves(params)))
    finally:
        unregister("sign_w")
    assert "sign_w" not in available()


@pytest.mark.parametrize("algo,kw", [
    ("efficient_adam", {}),
    ("onebit_adam", {}),
    ("fedadam_ssm", dict(error_feedback=True, alpha=0.25)),
])
def test_stateful_compressor_runs_on_shardmap_driver(algo, kw):
    """The shard_map spatial driver THREADS per-client compressor state
    (it used to raise NotImplementedError for any stateful compressor):
    the round builds, runs, and carries a populated state tree across
    rounds.  A 1-device client mesh exercises the exact same MANUAL
    region as the multi-device CI mesh (tests/test_fed_equivalence.py
    pins multi-device equivalence)."""
    from repro import compat

    params, batches, loss_fn, _ = _toy()
    C = 1
    one = lambda t: jax.tree.map(lambda x: x[:1], t)
    fed = FedConfig(algorithm=algo, n_clients=C, local_epochs=2,
                    adam=AdamHyper(lr=0.05), client_mode="vmap",
                    client_axes=("data",), **kw)
    rf = jax.jit(make_fl_round(fed, loss_fn))
    st = fed_init(fed, params)
    assert st.client_state is not None
    mesh = jax.make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        st, mets = rf(st, one(batches))
        st2, mets = rf(st, one(batches))
    assert st2.client_state is not None, "state dropped by the mesh driver"
    err_leaves = jax.tree.leaves(st2.client_state["comp"])
    assert all(x.shape[0] == C for x in err_leaves)
    err_norm = sum(float(jnp.sum(jnp.abs(x))) for x in err_leaves)
    assert np.isfinite(err_norm) and err_norm > 0, \
        "EF residual never populated — compression dropped nothing?"
    assert np.isfinite(float(jnp.mean(mets["loss"])))
