"""Kernel-vs-reference parity for the sparsifier backend dispatch
(core/sparsify.resolve_backend + the fused compress path).

Runs entirely in Pallas interpret mode (the kernels' ops.py wrappers
interpret automatically off-TPU), so CPU CI exercises the real kernel
code paths.  Contract under test (docs/kernels.md):

* backend resolution: config override > REPRO_SPARSIFY_BACKEND env >
  auto (TPU -> kernel, else reference);
* kernel threshold masks agree with the exact top-k support within the
  documented over-selection bound (``overselect_bound``) and are level
  sets of |score|;
* the fused ``ssm_apply_ef`` pass is BIT-EXACT against the composed jnp
  ops (mask apply, ``value_dtype`` round-trip, f32 residual subtract)
  given the same tau — including the error-feedback residual;
* odd / tile-padded / multi-dim shapes and bf16/f32 dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masks, sparsify as S
from repro.core.compressors.base import Deltas, tree_add, tree_sub
from repro.core.compressors.topk import (IndependentTopKCompressor,
                                         SharedTopKCompressor)
from repro.kernels.ssm_apply.ref import ssm_apply_ef_ref
from repro.kernels.topk_mask.ops import overselect_bound, select_tau_kernel

# odd (non-tile), padded (not a multiple of 8*1024), exact-tile, multi-dim
SHAPES = [(37,), (3, 5, 7), (8, 1024), (50_000,), (20_011,)]
DTYPES = [jnp.float32, jnp.bfloat16]
ALPHA = 0.05


def _tree(key, dtype=jnp.float32, shapes=SHAPES):
    ks = jax.random.split(key, len(shapes))
    return {f"l{i}": jax.random.normal(k, s).astype(dtype)
            for i, (k, s) in enumerate(zip(ks, shapes))}


# ---------------------------------------------------------------------------
# Backend resolution
# ---------------------------------------------------------------------------


def test_resolve_backend_precedence(monkeypatch):
    monkeypatch.delenv(S.SPARSIFY_BACKEND_ENV, raising=False)
    # auto rule: this suite runs off-TPU -> reference
    assert S.resolve_backend() == "reference"
    assert S.resolve_backend("auto") == "reference"
    # env overrides auto
    monkeypatch.setenv(S.SPARSIFY_BACKEND_ENV, "kernel")
    assert S.resolve_backend() == "kernel"
    # explicit config override beats env
    assert S.resolve_backend("reference") == "reference"
    with pytest.raises(ValueError):
        S.resolve_backend("cuda")
    monkeypatch.setenv(S.SPARSIFY_BACKEND_ENV, "nonsense")
    with pytest.raises(ValueError):
        S.resolve_backend()


def test_fedconfig_plumbs_backend():
    from repro.core.compressors import make_compressor
    from repro.core.fed import FedConfig
    fed = FedConfig(algorithm="fedadam_ssm", exact_topk=False,
                    sparsify_backend="kernel")
    comp = make_compressor(fed)
    assert comp.sparsify_backend == "kernel"
    assert comp._kernel_path()
    # exact sort masks have no kernel realization -> composed path
    fed = FedConfig(algorithm="fedadam_top", exact_topk=True,
                    sparsify_backend="kernel")
    assert not make_compressor(fed)._kernel_path()


# ---------------------------------------------------------------------------
# Mask support parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
def test_kernel_masks_support_within_tolerance(dtype):
    tree = _tree(jax.random.PRNGKey(0), dtype)
    mk = S.tree_topk_masks(jax.tree.map(jnp.abs, tree), ALPHA,
                           exact=False, backend="kernel")
    for name, x in tree.items():
        k = S.k_for(x.size, ALPHA)
        cnt = int(mk[name].sum())
        assert k <= cnt <= k + overselect_bound(k, x.size), (name, cnt, k)
        a = jnp.abs(x.astype(jnp.float32))
        kept_min = jnp.min(jnp.where(mk[name], a, jnp.inf))
        drop_max = jnp.max(jnp.where(mk[name], -jnp.inf, a))
        assert float(kept_min) >= float(drop_max) - 1e-6


def test_kernel_vs_reference_masks_agree_on_support():
    """Both backends produce level-set masks of the same scores: they may
    disagree only inside the over-selection band near tau."""
    tree = _tree(jax.random.PRNGKey(1))
    score = jax.tree.map(jnp.abs, tree)
    mk = S.tree_topk_masks(score, ALPHA, exact=False, backend="kernel")
    mr = S.tree_topk_masks(score, ALPHA, exact=False, backend="reference")
    for name, x in tree.items():
        k = S.k_for(x.size, ALPHA)
        sym_diff = int(jnp.sum(mk[name] ^ mr[name]))
        assert sym_diff <= 2 * overselect_bound(k, x.size), (name, sym_diff)
        # the top-k/2 by magnitude are in BOTH masks (deep inside the band)
        top = S.topk_mask_exact(x, max(1, k // 2))
        assert bool(jnp.all(jnp.where(top, mk[name], True)))
        assert bool(jnp.all(jnp.where(top, mr[name], True)))


# ---------------------------------------------------------------------------
# Fused compress: bit-exact vs composed jnp ops
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("value_dtype", [None, "bfloat16"])
def test_fused_compress_bit_exact_vs_composed(dtype, value_dtype):
    key = jax.random.PRNGKey(2)
    dW = _tree(key, dtype)
    dM = jax.tree.map(lambda x: x * jnp.asarray(0.1, x.dtype), dW)
    dV = jax.tree.map(jnp.abs, _tree(jax.random.PRNGKey(3), dtype))

    sW, sM, sV, err, mask = S.tree_shared_compress_fused(
        None, dW, dM, dV, ALPHA, value_dtype=value_dtype,
        with_residual=True)

    for name in dW:
        w, m, v = dW[name], dM[name], dV[name]
        tau, _ = select_tau_kernel(w, S.k_for(w.size, ALPHA))
        rw, rm, rv, rerr = ssm_apply_ef_ref(tau, w, m, v,
                                            value_dtype=value_dtype)
        for got, want in ((sW[name], rw), (sM[name], rm), (sV[name], rv),
                          (err[name], rerr)):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want), err_msg=name)
        # and vs the tree-level composed ops the reference path uses
        keep = jnp.abs(w.astype(jnp.float32)) >= tau
        assert bool(jnp.all(mask[name] == keep))
    comp_sW = jax.tree.map(
        lambda x, mm: jnp.where(mm, x, jnp.zeros((), x.dtype)), dW, mask)
    if value_dtype is not None:
        vdt = jnp.dtype(value_dtype)
        comp_sW = jax.tree.map(lambda x: x.astype(vdt).astype(x.dtype),
                               comp_sW)
    comp_err = tree_sub(dW, comp_sW)
    for name in dW:
        np.testing.assert_array_equal(np.asarray(err[name]),
                                      np.asarray(comp_err[name]))


def test_shared_compressor_kernel_path_bit_exact_ef(monkeypatch):
    """End-to-end: SharedTopKCompressor on the kernel backend carries a
    residual bit-identical to the composed ops over its own masks, and a
    second round consumes it (EF input = deltas + residual)."""
    monkeypatch.setenv(S.SPARSIFY_BACKEND_ENV, "kernel")
    dW = _tree(jax.random.PRNGKey(4))
    dM = jax.tree.map(lambda x: x * 0.1, dW)
    dV = jax.tree.map(jnp.abs, _tree(jax.random.PRNGKey(5)))
    deltas = Deltas(dW, dM, dV)
    comp = SharedTopKCompressor(alpha=ALPHA, exact_topk=False,
                                error_feedback=True,
                                value_dtype="bfloat16")
    assert comp._kernel_path()
    state = comp.init_state(dW)
    packed, state1, _ = comp.compress(deltas, state)

    comp_err = tree_sub(dW, packed.W)
    for name in dW:
        np.testing.assert_array_equal(np.asarray(state1["err"][name]),
                                      np.asarray(comp_err[name]))
        # shared support: M and V vanish exactly where W does
        zw = np.asarray(packed.W[name]) == 0
        assert (np.asarray(packed.M[name])[zw] == 0).all()
        assert (np.asarray(packed.V[name])[zw] == 0).all()

    # round 2: the EF-adjusted input is deltas + residual
    packed2, _, _ = comp.compress(deltas, state1)
    dW_eff = tree_add(dW, state1["err"])
    tau, _ = select_tau_kernel(dW_eff["l3"], S.k_for(dW["l3"].size, ALPHA))
    rw = ssm_apply_ef_ref(tau, dW_eff["l3"], dM["l3"], dV["l3"],
                          value_dtype="bfloat16")[0]
    np.testing.assert_array_equal(np.asarray(packed2.W["l3"]),
                                  np.asarray(rw))


@pytest.mark.parametrize("rule", ["ssm_m", "fairness_top"])
def test_fused_compress_score_rules(rule, monkeypatch):
    """Non-ssm_w rules stream a separate score tensor; mask must come
    from that score, applied to all three deltas."""
    monkeypatch.setenv(S.SPARSIFY_BACKEND_ENV, "kernel")
    dW = _tree(jax.random.PRNGKey(6))
    dM = _tree(jax.random.PRNGKey(7))
    dV = jax.tree.map(jnp.abs, _tree(jax.random.PRNGKey(8)))
    comp = SharedTopKCompressor(rule=rule, alpha=ALPHA, exact_topk=False)
    packed, _, _ = comp.compress(Deltas(dW, dM, dV), None)
    score = masks.shared_score_tree(rule, dW, dM, dV)
    for name in dW:
        k = S.k_for(dW[name].size, ALPHA)
        tau, _ = select_tau_kernel(score[name], k)
        keep = jnp.abs(score[name].astype(jnp.float32)) >= tau
        np.testing.assert_array_equal(
            np.asarray(packed.W[name]),
            np.asarray(jnp.where(keep, dW[name], 0)), err_msg=name)


def test_global_scope_kernel_parity():
    dW = _tree(jax.random.PRNGKey(9))
    dM = jax.tree.map(lambda x: x * 0.1, dW)
    dV = jax.tree.map(jnp.abs, dW)
    sW, _, _, err, mask = S.tree_shared_compress_fused(
        None, dW, dM, dV, ALPHA, scope="global", with_residual=True)
    d = sum(x.size for x in jax.tree.leaves(dW))
    k = S.k_for(d, ALPHA)
    kept = sum(int(m.sum()) for m in jax.tree.leaves(mask))
    assert k <= kept <= k + overselect_bound(k, d)
    # one global tau: kept min across ALL leaves >= dropped max
    a = jnp.concatenate([jnp.abs(x.reshape(-1)) for x in
                         jax.tree.leaves(dW)])
    mflat = jnp.concatenate([m.reshape(-1) for m in jax.tree.leaves(mask)])
    assert float(jnp.min(jnp.where(mflat, a, jnp.inf))) >= \
        float(jnp.max(jnp.where(mflat, -jnp.inf, a))) - 1e-6
    # residual + kept values reassemble the input exactly (vdt=None)
    recon = tree_add(sW, err)
    for name in dW:
        np.testing.assert_allclose(np.asarray(recon[name]),
                                   np.asarray(dW[name]), atol=1e-6)


def test_independent_compressor_kernel_masks(monkeypatch):
    monkeypatch.setenv(S.SPARSIFY_BACKEND_ENV, "kernel")
    dW = _tree(jax.random.PRNGKey(10))
    dM = _tree(jax.random.PRNGKey(11))
    dV = jax.tree.map(jnp.abs, _tree(jax.random.PRNGKey(12)))
    comp = IndependentTopKCompressor(alpha=ALPHA, exact_topk=False)
    packed, _, _ = comp.compress(Deltas(dW, dM, dV), None)
    for tree, carrier in ((dW, packed.W), (dM, packed.M), (dV, packed.V)):
        for name, x in tree.items():
            k = S.k_for(x.size, ALPHA)
            kept = int(jnp.sum(carrier[name] != 0))
            # random normals: no collisions with exact zero
            assert kept <= k + overselect_bound(k, x.size), (name, kept)
            assert kept >= int(0.9 * k)
