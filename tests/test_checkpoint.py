import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (load_fed_state, load_pytree, save_fed_state,
                              save_pytree)
from repro.core import FedConfig, fed_init


def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": (jnp.ones((2, 3)),
                                         {"c": jnp.zeros(5, jnp.int32)})}
    p = tmp_path / "ck.npz"
    save_pytree(tree, p, meta={"note": "test"})
    out = load_pytree(tree, p)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fed_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros(4)}
    fed = FedConfig(n_clients=3)
    st = fed_init(fed, params)
    st = st._replace(round=jnp.int32(7))
    p = tmp_path / "fed.npz"
    save_fed_state(st, p)
    out = load_fed_state(st, p)
    assert int(out.round) == 7
    for x, y in zip(jax.tree.leaves(st.W), jax.tree.leaves(out.W)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
