"""Per-kernel interpret-mode validation: sweep shapes x dtypes against the
pure-jnp ref.py oracles (per the brief, every Pallas kernel gets this)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsify as S
from repro.kernels.fused_adam import ops as fa_ops
from repro.kernels.fused_adam.ref import fused_adam_ref
from repro.kernels.packed_topk import ops as pk_ops
from repro.kernels.packed_topk.ref import (packed_apply_ef_ref,
                                           packed_hist_ref,
                                           packed_mask_apply_ref,
                                           refine_taus)
from repro.kernels.ssm_apply import ops as sa_ops
from repro.kernels.ssm_apply.ref import ssm_apply_ref
from repro.kernels.topk_mask import ops as tm_ops
from repro.kernels.topk_mask.ref import (log2_taus, select_tau_ref,
                                         topk_mask_exact, topk_mask_ref)
from repro.optim import AdamHyper

SHAPES = [(64,), (8192,), (8, 1024), (3, 5, 7), (50_000,), (2, 8192, 3)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bias_correction", [False, True])
def test_fused_adam_allclose(shape, dtype, bias_correction):
    h = AdamHyper(lr=0.01, bias_correction=bias_correction)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    w, g, m, v = (jax.random.normal(k, shape).astype(dtype) for k in keys)
    v = jnp.abs(v)
    count = jnp.int32(3)
    out_k = fa_ops.fused_adam(w, g, m, v, h, count)
    sc = fa_ops._effective_scalars(h, count)
    out_r = fused_adam_ref(sc, w, g, m, v)
    for a, b in zip(out_k, out_r):
        atol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)


@pytest.mark.parametrize("n,alpha", [(8192, 0.05), (50_000, 0.05),
                                     (100_000, 0.01), (9000, 0.3),
                                     (8192, 0.99)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_topk_mask_kernel_matches_ref(n, alpha, dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), (n,)).astype(dtype)
    k = max(1, int(alpha * n))
    mask_k, tau_k, cnt = tm_ops.topk_mask_kernel(x, k)
    mask_r = topk_mask_ref(x, k)
    assert bool(jnp.all(mask_k == mask_r)), "kernel != jnp oracle"
    # selection quality vs exact top-k: the enforced contract is
    # overselect_bound — assert against it, never a re-derived constant
    assert int(mask_k.sum()) >= min(k, n)
    assert int(mask_k.sum()) <= k + tm_ops.overselect_bound(k, n)
    # level-set property: kept |x| >= dropped |x|
    kept_min = jnp.min(jnp.where(mask_k, jnp.abs(x.astype(jnp.float32)),
                                 jnp.inf))
    drop_max = jnp.max(jnp.where(mask_k, -jnp.inf,
                                 jnp.abs(x.astype(jnp.float32))))
    assert float(kept_min) >= float(drop_max) - 1e-6


@pytest.mark.parametrize("shape", [(8192,), (50_000,), (8, 4096)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_ssm_apply_matches_ref(shape, dtype):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    dw, dm, dv = (jax.random.normal(k, shape).astype(dtype) for k in keys)
    tau = jnp.float32(0.7)
    out_k = sa_ops.ssm_apply(tau, dw, dm, dv)
    out_r = ssm_apply_ref(tau, dw, dm, dv)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("with_residual", [False, True])
@pytest.mark.parametrize("value_dtype", [None, "bfloat16"])
def test_ssm_apply_ef_matches_ref(with_residual, value_dtype):
    from repro.kernels.ssm_apply.ref import ssm_apply_ef_ref
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    dw, dm, dv, score = (jax.random.normal(k, (50_000,)) for k in keys)
    tau = jnp.float32(0.9)
    out_k = sa_ops.ssm_apply_ef(tau, dw, dm, dv, score,
                                with_residual=with_residual,
                                value_dtype=value_dtype)
    out_r = ssm_apply_ef_ref(tau, dw, dm, dv, score,
                             with_residual=with_residual,
                             value_dtype=value_dtype)
    assert len(out_k) == (4 if with_residual else 3)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_pipeline_equals_algorithm():
    """topk_mask kernel + ssm_apply == the core sparsify path semantics."""
    n, alpha = 30_000, 0.05
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    dw, dm, dv = (jax.random.normal(k, (n,)) for k in keys)
    k = max(1, int(alpha * n))
    mask, tau, _ = tm_ops.topk_mask_kernel(dw, k)
    sw, sm, sv = sa_ops.ssm_apply(tau, dw, dm, dv)
    assert bool(jnp.all((sw != 0) == mask))
    assert bool(jnp.all(jnp.where(mask, dm, 0) == sm))
    assert bool(jnp.all(jnp.where(mask, dv, 0) == sv))


# --- packed cohort kernels (kernels/packed_topk) ---------------------------

PACKED_SHAPES = ((37,), (3, 5, 7), (8, 1024), (2000,), (50_000,))


def _packed_fixture(seed, dtype, groups=None):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(PACKED_SHAPES))
    leaves = [jax.random.normal(k, s).astype(dtype)
              for k, s in zip(keys, PACKED_SHAPES)]
    layout = S.plan_packed_layout(leaves, groups)
    return layout, leaves


def _select_inputs_ref(layout, leaves, xp, alpha=0.05):
    """taus2/ks/ns through the REF histogram, so kernel-vs-ref apply
    comparisons share identical prefetch operands."""
    ks = jnp.asarray([S.k_for(n, alpha) for n in layout.seg_sizes],
                     jnp.float32)
    ns = jnp.asarray(layout.seg_sizes, jnp.float32)
    absmax = S._segment_absmax(layout, leaves)
    edges = jnp.stack([log2_taus(a) for a in absmax])
    c1 = packed_hist_ref(xp, layout.seg_ids, edges)
    return refine_taus(c1, edges, absmax, ks), ks, ns, edges


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("scope", ["per_tensor", "global"])
def test_packed_hist_kernel_matches_ref(dtype, scope):
    groups = None if scope == "per_tensor" else [0] * len(PACKED_SHAPES)
    layout, leaves = _packed_fixture(7, dtype, groups)
    xp = layout.pack(leaves)
    _, _, _, edges = _select_inputs_ref(layout, leaves, xp)
    c_k = pk_ops.packed_hist_kernel(xp, layout.seg_ids, edges)
    c_r = packed_hist_ref(xp, layout.seg_ids, edges)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


@pytest.mark.parametrize("with_residual", [False, True])
@pytest.mark.parametrize("value_dtype", [None, "bfloat16"])
@pytest.mark.parametrize("has_score", [False, True])
def test_packed_apply_ef_matches_ref(with_residual, value_dtype, has_score):
    layout, w_leaves = _packed_fixture(8, jnp.float32)
    _, m_leaves = _packed_fixture(9, jnp.float32)
    _, v_leaves = _packed_fixture(10, jnp.float32)
    wp, mp, vp = (layout.pack(ls) for ls in (w_leaves, m_leaves, v_leaves))
    if has_score:
        _, s_leaves = _packed_fixture(11, jnp.float32)
        sp, score_leaves = layout.pack(s_leaves), s_leaves
    else:
        sp, score_leaves = None, w_leaves
    taus2, ks, ns, _ = _select_inputs_ref(
        layout, score_leaves, wp if sp is None else sp)
    out_k = pk_ops.packed_apply_ef(taus2, layout.seg_ids, ks, ns,
                                   wp, mp, vp, sp,
                                   with_residual=with_residual,
                                   value_dtype=value_dtype)
    out_r = packed_apply_ef_ref(taus2, layout.seg_ids, ks, ns,
                                (wp, mp, vp), sp,
                                with_residual=with_residual,
                                value_dtype=value_dtype)
    assert len(out_k) == len(out_r) == (6 if with_residual else 5)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("dtype", DTYPES)
def test_packed_mask_apply_matches_ref(dtype):
    # independent-compress shape: one buffer, one tau segment per leaf
    layout, leaves = _packed_fixture(12, dtype)
    xp = layout.pack(leaves)
    taus2, ks, ns, _ = _select_inputs_ref(layout, leaves, xp)
    out_k = pk_ops.packed_mask_apply(taus2, layout.seg_ids, ks, ns, xp,
                                     value_dtype="bfloat16")
    out_r = packed_mask_apply_ref(taus2, layout.seg_ids, ks, ns, xp,
                                  value_dtype="bfloat16")
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_tau_equals_perleaf_tau():
    """The hinge of the whole packed design: each segment's tau (and
    kept count) is BITWISE the per-leaf 3-pass select_tau_kernel's."""
    layout, leaves = _packed_fixture(13, jnp.float32)
    xp = layout.pack(leaves)
    taus2, ks, ns, _ = _select_inputs_ref(layout, leaves, xp)
    outs = pk_ops.packed_mask_apply(taus2, layout.seg_ids, ks, ns, xp)
    taus, cnts = outs[-2][:, 0], outs[-1][:, 0]
    for i, leaf in enumerate(leaves):
        tau_i, cnt_i = tm_ops.select_tau_kernel(
            leaf, S.k_for(leaf.size, 0.05))
        assert float(taus[i]) == float(tau_i), f"leaf {i} tau"
        assert float(cnts[i]) == float(cnt_i), f"leaf {i} count"


def test_fused_adam_in_optimizer_loop():
    """use_kernel=True path of adam_step converges like the jnp path."""
    from repro.optim import adam_init, adam_step
    h = AdamHyper(lr=0.05)
    w_true = jax.random.normal(jax.random.PRNGKey(4), (9000,))

    def run(use_kernel):
        w = {"p": jnp.zeros((9000,))}
        st = adam_init(w)
        for _ in range(20):
            g = jax.tree.map(lambda x: x - w_true, w)
            w, st = adam_step(w, g, st, h, use_kernel=use_kernel)
        return w["p"]

    a, b = run(False), run(True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# wirepack: word-level pack/unpack parity (kernel vs oracle, bitwise)
# ---------------------------------------------------------------------------

from repro.kernels.wirepack import ops as wp_ops
from repro.kernels.wirepack.ref import (pack_bbit_ref, pack_mask_bits_ref,
                                        pack_sign_scale_ref, pack_words_ref,
                                        unpack_bbit_ref,
                                        unpack_mask_bits_ref,
                                        unpack_sign_scale_ref,
                                        unpack_words_ref)
from repro.kernels.wirepack.wirepack import (pack_words_2d, unpack_words_2d)

_WP_ROWS = [32, 96]  # row-group quantum is 32; cover multi-group grids


@pytest.mark.parametrize("rows", _WP_ROWS)
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
def test_wirepack_words_kernel_matches_ref(rows, bits):
    """The one kernel pair under everything: (rows,128) codes <->
    uint32 words, bitwise against the jnp shift/mask oracle."""
    codes = jax.random.randint(jax.random.PRNGKey(bits * 100 + rows),
                               (rows, 128), 0, 1 << bits, jnp.int32)
    words = pack_words_2d(codes, bits=bits, interpret=True)
    assert words.dtype == jnp.uint32
    assert words.shape == (rows * bits // 32, 128)
    assert bool(jnp.all(words == pack_words_ref(codes, bits)))
    back = unpack_words_2d(words, bits=bits, interpret=True)
    assert bool(jnp.all(back == codes))
    assert bool(jnp.all(unpack_words_ref(words, bits) == codes))


@pytest.mark.parametrize("rows", _WP_ROWS)
def test_wirepack_mask_bits_matches_ref(rows):
    sup = (jax.random.uniform(jax.random.PRNGKey(rows), (rows, 128))
           < 0.3).astype(jnp.int32)
    words = wp_ops.pack_mask_bits(sup)
    assert bool(jnp.all(words == pack_mask_bits_ref(sup)))
    assert bool(jnp.all(wp_ops.unpack_mask_bits(words) == sup))
    assert bool(jnp.all(unpack_mask_bits_ref(words) == sup))


@pytest.mark.parametrize("rows", _WP_ROWS)
def test_wirepack_sign_scale_matches_ref(rows):
    """Exact on sign_quant carriers: blocks are two-valued +-scale, so
    the decode is bitwise the carrier."""
    from repro.core import quantize
    x = jax.random.normal(jax.random.PRNGKey(rows + 1), (rows * 128,))
    carrier = quantize.sign_quant(x, block=1024).reshape(rows, 128)
    wk, sk = wp_ops.pack_sign_scale(carrier)
    wr, sr = pack_sign_scale_ref(carrier)
    assert bool(jnp.all(wk == wr)) and bool(jnp.all(sk == sr))
    out_k = wp_ops.unpack_sign_scale(wk, sk)
    out_r = unpack_sign_scale_ref(wr, sr)
    assert bool(jnp.all(out_k == carrier))
    assert bool(jnp.all(out_r == carrier))


@pytest.mark.parametrize("rows", _WP_ROWS)
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_wirepack_bbit_matches_ref(rows, bits):
    qmax = (1 << (bits - 1)) - 1
    codes = jax.random.randint(jax.random.PRNGKey(bits * 7 + rows),
                               (rows, 128), -qmax, qmax + 1, jnp.int32)
    wk = wp_ops.pack_bbit(codes, bits)
    wr = pack_bbit_ref(codes, bits)
    assert bool(jnp.all(wk == wr))
    assert bool(jnp.all(wp_ops.unpack_bbit(wk, bits) == codes))
    assert bool(jnp.all(unpack_bbit_ref(wr, bits) == codes))
