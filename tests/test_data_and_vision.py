"""Data pipeline (Dirichlet non-IID partitioner) + the paper's vision
models (CNN / VGG-11 / ResNet-18)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (client_batches, dirichlet_partition, iid_partition,
                        synthetic_image_dataset, synthetic_tokens)
from repro.models.vision import build_vision


def test_dirichlet_partition_covers_and_skews():
    imgs, labels = synthetic_image_dataset("fashion_mnist", 2000)
    parts = dirichlet_partition(labels, n_clients=10, theta=0.1, seed=0)
    assert all(len(p) > 0 for p in parts)
    assert sum(len(p) for p in parts) >= 2000 - 10  # near-partition
    # skew: at theta=0.1 some client should be dominated by few classes
    fracs = []
    for p in parts:
        counts = np.bincount(labels[p], minlength=10)
        fracs.append(counts.max() / max(1, counts.sum()))
    assert max(fracs) > 0.5
    # IID partition has near-uniform class fractions
    parts_iid = iid_partition(2000, 10)
    c0 = np.bincount(labels[parts_iid[0]], minlength=10) / len(parts_iid[0])
    assert c0.max() < 0.3


def test_client_batches_shapes():
    imgs, labels = synthetic_image_dataset("cifar10", 500)
    parts = iid_partition(500, 5)
    (bx, by), weights = client_batches([imgs, labels], parts, 8)
    assert bx.shape == (5, 8, 32, 32, 3) and by.shape == (5, 8)
    assert weights.shape == (5,)


def test_synthetic_tokens_topic_shift():
    a = synthetic_tokens(100, 64, 1000, topic=0)
    b = synthetic_tokens(100, 64, 1000, topic=3)
    # different topics => visibly different unigram distributions
    ha = np.bincount(a.ravel(), minlength=1000)
    hb = np.bincount(b.ravel(), minlength=1000)
    overlap = np.minimum(ha, hb).sum() / ha.sum()
    assert overlap < 0.9


@pytest.mark.parametrize("name", ["cnn", "vgg11", "resnet18"])
def test_vision_models_forward_and_grad(name):
    params, fwd, loss_fn, acc_fn, ds = build_vision(name, width=0.25)
    imgs, labels = synthetic_image_dataset(ds, 64, seed=1)
    batch = (jnp.asarray(imgs), jnp.asarray(labels))
    logits = fwd(params, batch[0][:4])
    assert logits.shape == (4, 10)
    val, g = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    assert jnp.isfinite(val)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_cnn_learns_synthetic_prototypes():
    """The paper's CNN beats chance in a few full-batch steps (deeper
    models' learning curves are exercised by the benchmark suite, which
    runs them for whole FL rounds)."""
    params, fwd, loss_fn, acc_fn, ds = build_vision("cnn", width=0.25)
    imgs, labels = synthetic_image_dataset(ds, 256, seed=1)
    batch = (jnp.asarray(imgs), jnp.asarray(labels))
    lr = 0.1

    @jax.jit
    def step(p):
        g = jax.grad(loss_fn)(p, batch)
        return jax.tree.map(lambda w, gg: w - lr * gg, p, g)

    for _ in range(30):
        params = step(params)
    acc = float(acc_fn(params, batch))
    assert acc > 0.3, acc
