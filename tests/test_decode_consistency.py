"""Decode-path correctness: teacher-forced decode must reproduce the
training forward logits; prefill caches must seed decode exactly; ring
caches must equal full caches under the same window."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.configs.base import ArchConfig
from repro.models import (cache_meta, decode_step, forward, init_params,
                          materialize, prefill)

ARCHS = ["starcoder2-3b", "gemma3-27b", "mamba2-1-3b",
         "deepseek-v2-lite-16b", "jamba-1-5-large-398b", "whisper-base"]


def _no_drop(cfg):
    """Raise MoE capacity so no tokens drop: capacity-based routing
    legitimately differs between a parallel forward (per-row capacity over
    s tokens) and one-token decode — parity holds in the no-drop regime."""
    if not any(sp.moe for sp in cfg.layer_pattern):
        return cfg
    pattern = tuple(
        dataclasses.replace(
            sp, moe=dataclasses.replace(sp.moe, capacity_factor=8.0))
        if sp.moe else sp
        for sp in cfg.layer_pattern)
    return dataclasses.replace(cfg, layer_pattern=pattern)


def _setup(arch, s=24, dtype="float32"):
    # parity asserts run in f32: the chunked-SSD parallel form vs the
    # sequential decode recurrence agree to 6e-6 in f32 but the bf16
    # rounding of the two different computation orders compounds through
    # deep heterogeneous stacks (measured 0.16 rel on jamba's 8-layer
    # pattern) — a property of mixed-precision scan algebra, not a bug;
    # bf16 end-to-end behaviour is covered by the smoke/serve tests.
    cfg = _no_drop(reduce_for_smoke(get_config(arch)))
    cfg = dataclasses.replace(cfg, dtype=dtype)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0,
                                cfg.vocab_size)
    kw = {}
    if cfg.stub_frontend and cfg.encoder is not None:
        kw["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, 16, cfg.d_model), jnp.float32)
    return cfg, params, tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Replaying tokens one-by-one through decode_step yields the same
    next-token logits as the parallel training forward."""
    cfg, params, tokens, kw = _setup(arch)
    if cfg.stub_frontend and cfg.encoder is None:
        pytest.skip("VLM prefix handled in forward-only tests")
    s = tokens.shape[1]
    fwd_logits, _ = jax.jit(
        lambda p, t: forward(cfg, p, t, remat="none", **kw))(params, tokens)

    seq = s + 4
    caches = materialize(cache_meta(cfg, 2, seq), jax.random.PRNGKey(3))
    if cfg.encoder is not None:
        # seed cross caches from prefill (they are static per request)
        _, pre_caches = jax.jit(
            lambda p, t: prefill(cfg, p, t, **kw))(params, tokens)
        def seed(c, pc):
            out = []
            for cd, pd in zip(c, pc):
                d = dict(cd)
                for k in ("cross_k", "cross_v"):
                    if k in pd:
                        d[k] = pd[k]
                out.append(d)
            return tuple(out)
        caches = seed(caches, jax.tree.map(lambda x: x, pre_caches))

    step = jax.jit(functools.partial(decode_step, cfg, seq_len=seq))
    errs = []
    for i in range(s):
        logits, caches = step(params, caches, jnp.int32(i), tokens[:, i])
        errs.append(float(jnp.max(jnp.abs(
            logits.astype(jnp.float32) -
            fwd_logits[:, i].astype(jnp.float32)))))
    # bf16 params, f32 stats: allow loose atol but demand real agreement
    scale = float(jnp.max(jnp.abs(fwd_logits.astype(jnp.float32)))) + 1e-6
    assert max(errs) / scale < 0.05, (arch, max(errs), scale)


def test_ring_cache_equals_full_cache():
    """A windowed layer decoded with a ring cache (cache_len = window) must
    match the same decode with a full cache + window mask."""
    cfg = reduce_for_smoke(get_config("gemma3-27b"))
    # make every layer windowed with a small window
    params = init_params(cfg, jax.random.PRNGKey(0))
    s = 96
    window = cfg.layer_pattern[0].attention.window
    assert window is not None and window < s
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0,
                                cfg.vocab_size)
    # full cache (ring only engages when cache_len < seq... force both ways)
    caches_ring = materialize(cache_meta(cfg, 1, s), jax.random.PRNGKey(2))
    step_ring = jax.jit(functools.partial(decode_step, cfg, seq_len=s))
    # full-cache variant: huge window so ring disabled
    big = dataclasses.replace(
        cfg,
        layer_pattern=tuple(
            dataclasses.replace(
                sp, attention=dataclasses.replace(sp.attention, window=None))
            for sp in cfg.layer_pattern),
        long_context_window=None)
    # manual masked decode replication is complex; instead check the ring
    # path is self-consistent: last-token logits finite + caches rotate
    logits = None
    for i in range(s):
        logits, caches_ring = step_ring(params, caches_ring, jnp.int32(i),
                                        tokens[:, i])
    assert bool(jnp.isfinite(logits).all())


def test_prefill_seeds_decode():
    """decode(prefill(prompt)) continues exactly like decoding the prompt
    token-by-token (full-cache archs)."""
    cfg, params, tokens, kw = _setup("starcoder2-3b", s=16)
    s = tokens.shape[1]
    seq = s + 4
    # path A: token-by-token
    caches_a = materialize(cache_meta(cfg, 2, seq), jax.random.PRNGKey(3))
    step = jax.jit(functools.partial(decode_step, cfg, seq_len=seq))
    for i in range(s):
        logits_a, caches_a = step(params, caches_a, jnp.int32(i),
                                  tokens[:, i])
    # path B: prefill then pad caches to seq
    logits_b, pre = jax.jit(lambda p, t: prefill(cfg, p, t))(params, tokens)
    def pad(x, full):
        pad_width = [(0, 0)] * x.ndim
        pad_width[3] = (0, full - x.shape[3])   # (rep, grp, b, S, kv, hd)
        return jnp.pad(x, pad_width)
    caches_b = jax.tree.map(lambda x: pad(x, seq), pre)
    np.testing.assert_allclose(np.asarray(logits_a, np.float32),
                               np.asarray(logits_b, np.float32),
                               atol=0.05, rtol=0.05)
    # continue decoding from both cache states with the same token
    nxt = jnp.argmax(logits_a, -1).astype(jnp.int32) % cfg.vocab_size
    la, _ = step(params, caches_a, jnp.int32(s), nxt)
    lb, _ = step(params, caches_b, jnp.int32(s), nxt)
    np.testing.assert_allclose(np.asarray(la, np.float32),
                               np.asarray(lb, np.float32),
                               atol=0.05, rtol=0.05)
