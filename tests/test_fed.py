"""Core FL-round behaviour: paper-exactness properties + convergence."""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, fed_init, make_fl_round
from repro.core.fed import _local_adam, active_client_count
from repro.optim import AdamHyper, adam_init, adam_step


def _toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4)) * 0.1, "b": jnp.zeros((4,))}
    C = 4
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, 16, 8))
    w_true = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    ys = jnp.einsum("cbi,ij->cbj", xs, w_true)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    return params, (xs, ys), loss_fn, C


def _run(algo, rounds=8, alpha=0.25, mode="scan", agg="dense", C=4, L=3,
         **kw):
    params, batches, loss_fn, _ = _toy()
    fed = FedConfig(algorithm=algo, alpha=alpha, local_epochs=L,
                    n_clients=C, adam=AdamHyper(lr=0.05),
                    client_mode=mode, aggregate=agg, **kw)
    rf = jax.jit(make_fl_round(fed, loss_fn))
    st = fed_init(fed, params)
    losses = []
    for _ in range(rounds):
        st, mets = rf(st, batches)
        losses.append(float(jnp.mean(mets["loss"])))
    return st, losses, mets


def test_alpha_one_equals_dense_fedadam():
    """alpha=1 makes FedAdam-SSM *exactly* FedAdam (Sec. VII setup)."""
    st_ssm, _, _ = _run("fedadam_ssm", alpha=1.0)
    st_dense, _, _ = _run("fedadam", alpha=1.0)
    for a, b in zip(jax.tree.leaves(st_ssm.W), jax.tree.leaves(st_dense.W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_scan_equals_vmap():
    for algo in ["fedadam_ssm", "fedadam_top", "fedadam", "fedsgd"]:
        st_s, _, _ = _run(algo, mode="scan")
        st_v, _, _ = _run(algo, mode="vmap")
        for a, b in zip(jax.tree.leaves(st_s.W), jax.tree.leaves(st_v.W)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


def test_sparse_gather_equals_dense_transport():
    st_d, _, _ = _run("fedadam_ssm", mode="vmap", agg="dense")
    st_s, _, _ = _run("fedadam_ssm", mode="vmap", agg="sparse_gather")
    for a, b in zip(jax.tree.leaves(st_d.W), jax.tree.leaves(st_s.W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_single_client_dense_equals_centralized_adam():
    """N=1, alpha=1: one FL round of L epochs == L centralized Adam steps
    (paper Eqs. 3-5, no bias correction)."""
    params, (xs, ys), loss_fn, _ = _toy()
    batch = (xs[:1], ys[:1])
    fed = FedConfig(algorithm="fedadam", alpha=1.0, local_epochs=5,
                    n_clients=1, adam=AdamHyper(lr=0.01))
    rf = jax.jit(make_fl_round(fed, loss_fn))
    st = fed_init(fed, params)
    st, _ = rf(st, batch)

    # centralized: plain Adam, same hyper, same data
    h = AdamHyper(lr=0.01)
    w = params
    opt = adam_init(params)
    single = (xs[0], ys[0])
    for _ in range(5):
        g = jax.grad(loss_fn)(w, single)
        w, opt = adam_step(w, g, opt, h)
    for a, b in zip(jax.tree.leaves(st.W), jax.tree.leaves(w)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # moments aggregated too (the paper's point vs Efficient-Adam)
    for a, b in zip(jax.tree.leaves(st.M), jax.tree.leaves(opt.m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("algo", ["fedadam_ssm", "fedadam_top", "fedadam",
                                  "ssm_m", "ssm_v", "fairness_top",
                                  "fedsgd", "efficient_adam"])
def test_converges_on_toy(algo):
    _, losses, _ = _run(algo, rounds=15)
    assert losses[-1] < losses[0] * 0.6, losses


def test_uplink_bits_ordering():
    """SSM < Top < dense bit counts at alpha=0.05 (Section IV).

    The round now reports WIRE-EXACT bits (8 * WirePayload.nbytes,
    core/wire.py), so this runs on a model large enough that the
    format's 4096-element alignment padding is second-order — on the
    36-parameter toy tree the bitmap padding alone exceeds the dense
    payload and honest accounting inverts the paper's ordering.  The
    padding arithmetic itself is pinned by tests/test_wire.py."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (512, 32)) * 0.1,
              "b": jnp.zeros((32,))}
    C = 2
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, 8, 512))
    w_true = jax.random.normal(jax.random.PRNGKey(2), (512, 32))
    ys = jnp.einsum("cbi,ij->cbj", xs, w_true)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    def bits(algo):
        fed = FedConfig(algorithm=algo, alpha=0.05, local_epochs=1,
                        n_clients=C, adam=AdamHyper(lr=0.05))
        rf = jax.jit(make_fl_round(fed, loss_fn))
        _, mets = rf(fed_init(fed, params), (xs, ys))
        return float(mets["uplink_bits"])

    assert bits("fedadam_ssm") < bits("fedadam_top") < bits("fedadam")


def test_shared_mask_alignment():
    """FedAdam-SSM: all three uploaded deltas share the SAME support."""
    params, batches, loss_fn, C = _toy()
    fed = FedConfig(algorithm="fedadam_ssm", alpha=0.3, local_epochs=2,
                    n_clients=C, adam=AdamHyper(lr=0.05), client_mode="vmap")
    st = fed_init(fed, params)
    # inspect one client's compression by reproducing the deltas
    from repro.core.fed import _tree_sub
    from repro.core import masks
    batch0 = jax.tree.map(lambda x: x[0], batches)
    w, m, v, _ = _local_adam(loss_fn, st.W, st.M, st.V, batch0, fed)
    dW, dM, dV = _tree_sub(w, st.W), _tree_sub(m, st.M), _tree_sub(v, st.V)
    mask = masks.shared_mask("ssm_w", dW, dM, dV, 0.3)
    from repro.core import sparsify as S
    for leaf_dw, leaf_mask in zip(jax.tree.leaves(dW),
                                  jax.tree.leaves(mask)):
        exact = S.topk_mask_exact(leaf_dw, S.k_for(leaf_dw.size, 0.3))
        assert bool(jnp.all(leaf_mask == exact))  # Eq. 28: mask=Top_k(|dW|)


def test_error_feedback_accumulates():
    """Beyond-paper EF: residuals carried to the next round change the
    trajectory and do not diverge."""
    st_ef, losses_ef, _ = _run("fedadam_ssm", rounds=12, alpha=0.1,
                               error_feedback=True)
    st_no, losses_no, _ = _run("fedadam_ssm", rounds=12, alpha=0.1)
    assert np.isfinite(losses_ef).all()
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(st_ef.W), jax.tree.leaves(st_no.W)))
    assert diff > 1e-7  # EF actually did something


@pytest.mark.parametrize("mode", ["scan", "shardmap"])
def test_ef_residual_is_carried_not_rezeroed(mode):
    """The round-2 payload must actually SEE round 1's residual: zeroing
    the carried residual between rounds changes the round-2 outcome, on
    the scan reference and on the shard_map mesh driver alike."""
    from repro import compat

    params, batches, loss_fn, C = _toy()
    if mode == "shardmap":
        C = 1
        batches = jax.tree.map(lambda x: x[:1], batches)
    fed = FedConfig(algorithm="fedadam_ssm", alpha=0.1, local_epochs=2,
                    n_clients=C, adam=AdamHyper(lr=0.05),
                    error_feedback=True,
                    client_mode=("scan" if mode == "scan" else "vmap"),
                    client_axes=(("data",) if mode == "shardmap"
                                 else None))
    rf = jax.jit(make_fl_round(fed, loss_fn))
    ctx = compat.set_mesh(jax.make_mesh((1,), ("data",))) \
        if mode == "shardmap" else contextlib.nullcontext()
    with ctx:
        st1, _ = rf(fed_init(fed, params), batches)
        err1 = st1.client_state["comp"]["err"]
        assert max(float(jnp.max(jnp.abs(x)))
                   for x in jax.tree.leaves(err1)) > 0
        st2, _ = rf(st1, batches)
        zeroed = st1._replace(client_state=dict(
            st1.client_state,
            comp={"err": jax.tree.map(jnp.zeros_like, err1)}))
        st2z, _ = rf(zeroed, batches)
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(st2.W), jax.tree.leaves(st2z.W)))
    assert diff > 1e-7, "round-2 payload ignored the carried residual"


def test_onebit_adam_with_warmup_converges():
    """1-bit Adam two-phase protocol: dense FedAdam warmup populates V,
    then the compressed phase uses it as a frozen precondition."""
    params, batches, loss_fn, C = _toy()
    warm = FedConfig(algorithm="fedadam", alpha=1.0, local_epochs=1,
                     n_clients=C, adam=AdamHyper(lr=0.02))
    rf_warm = jax.jit(make_fl_round(warm, loss_fn))
    st = fed_init(warm, params)
    for _ in range(3):
        st, mets = rf_warm(st, batches)
    onebit = FedConfig(algorithm="onebit_adam", alpha=1.0, local_epochs=1,
                       n_clients=C, adam=AdamHyper(lr=0.02))
    st1 = fed_init(onebit, st.W)
    st1 = st1._replace(M=st.M, V=st.V)
    rf1 = jax.jit(make_fl_round(onebit, loss_fn))
    losses = []
    for _ in range(15):
        st1, mets = rf1(st1, batches)
        losses.append(float(jnp.mean(mets["loss"])))
    assert losses[-1] < losses[0], losses


def test_active_client_count_boundaries():
    """The participation seam shared by the sync weight-masking round
    and the async dispatch pool (see its docstring): host-static int in
    [1, n_clients], Python (banker's) rounding, floor of one."""
    mk = lambda p, C: FedConfig(algorithm="fedadam_ssm", n_clients=C,
                                participation=p)
    # boundaries: 0.0 never builds an empty round; 1.0 is everyone
    assert active_client_count(mk(0.0, 7)) == 1
    assert active_client_count(mk(1.0, 7)) == 7
    assert active_client_count(mk(1.0, 1)) == 1
    # tiny fractions clamp up to one client
    assert active_client_count(mk(0.01, 20)) == 1
    # rounding is Python round (banker's at .5 ties)
    assert active_client_count(mk(0.5, 5)) == 2      # round(2.5) == 2
    assert active_client_count(mk(0.5, 7)) == 4      # round(3.5) == 4
    assert active_client_count(mk(0.25, 20)) == 5
    # invariant over a sweep: static int within [1, C]
    for C in (1, 3, 8, 20):
        for p in np.linspace(0.0, 1.0, 21):
            n = active_client_count(mk(float(p), C))
            assert isinstance(n, int) and 1 <= n <= C


def test_partial_participation():
    """Beyond-paper: sampling a fraction of clients per round still
    converges, reduces per-round uplink proportionally, and only active
    clients contribute to the aggregate."""
    params, batches, loss_fn, C = _toy()
    fed = FedConfig(algorithm="fedadam_ssm", alpha=0.5, local_epochs=2,
                    n_clients=C, adam=AdamHyper(lr=0.05),
                    participation=0.5)
    rf = jax.jit(make_fl_round(fed, loss_fn))
    st = fed_init(fed, params)
    losses = []
    for _ in range(15):
        st, mets = rf(st, batches)
        losses.append(float(jnp.mean(mets["loss"])))
    assert losses[-1] < losses[0]
    # uplink accounts only the sampled clients
    full = FedConfig(algorithm="fedadam_ssm", alpha=0.5, local_epochs=2,
                     n_clients=C, adam=AdamHyper(lr=0.05))
    rf_full = jax.jit(make_fl_round(full, loss_fn))
    _, mets_full = rf_full(fed_init(full, params), batches)
    assert float(mets["uplink_bits"]) == 0.5 * float(mets_full["uplink_bits"])
