"""Integration tests for tools/lint (repro-lint).

Each rule gets a known-bad and a known-good fixture tree under
``tests/lint_fixtures/<case>/`` which acts as a standalone lint root;
plus: the real repo must be clean against the committed baseline with no
stale entries, suppression comments must silence (only) their rule, and
the CLI must hold its exit-code contract.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from tools.lint import run_lint
from tools.lint.core import DEFAULT_BASELINE, Finding, write_baseline

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "lint_fixtures"


def _findings(case, rules=None, **kw):
    res = run_lint(FIXTURES / case, rules=rules, baseline_path=None, **kw)
    return res.findings


# -- pallas-contract -------------------------------------------------------


def test_pallas_bad_flags_misalignment_and_vmem():
    found = _findings("pallas_bad", rules=["pallas-contract"])
    msgs = [f.message for f in found]
    assert any("(3, 100)" in m and "not aligned" in m for m in msgs), msgs
    assert any("VMEM estimate" in m and "vmem_hog" in m for m in msgs), msgs
    # anchored at real lines in the fixture file
    assert all(f.path == "src/repro/kernels/demo/demo.py" for f in found)
    assert all(f.line > 0 for f in found)


def test_pallas_good_is_clean():
    assert _findings("pallas_good", rules=["pallas-contract"]) == []


def test_vmem_budget_is_configurable():
    # the good fixture's (8, 1024) f32 spec pair is ~64 KiB doubled;
    # a 0.01 MiB budget must flag it
    found = _findings("pallas_good", rules=["pallas-contract"],
                      vmem_budget_mb=0.01)
    assert any("VMEM estimate" in f.message for f in found)


# -- jit-hazard ------------------------------------------------------------


def test_jit_bad_flags_every_hazard_class():
    found = _findings("jit_bad", rules=["jit-hazard"])
    msgs = " | ".join(f.message for f in found)
    assert "host cast" in msgs
    assert ".item()" in msgs
    assert "np.asarray" in msgs
    assert "if thresh > 0.5" in msgs
    assert len(found) == 4, found


def test_jit_good_is_clean():
    assert _findings("jit_good", rules=["jit-hazard"]) == []


# -- ref-parity ------------------------------------------------------------


def test_refparity_bad_flags_missing_oracle_and_test():
    found = _findings("refparity_bad", rules=["ref-parity"])
    msgs = " | ".join(f.message for f in found)
    assert "`orphan_kernel` has no `orphan_ref`" in msgs
    assert "`orphan_kernel` is not referenced" in msgs
    # docstring mention must not count as a test reference
    assert "`tested_only` has no `tested_only_ref`" in msgs
    assert "`tested_only` is not referenced" not in msgs
    assert len(found) == 3, found


def test_refparity_good_is_clean():
    assert _findings("refparity_good", rules=["ref-parity"]) == []


# -- bits-accounting -------------------------------------------------------


def test_bits_bad_flags_missing_bits_and_doc_drift():
    found = _findings("bits_bad", rules=["bits-accounting"])
    msgs = " | ".join(f.message for f in found)
    assert "`no_bits` resolves to ['NoBitsCompressor']" in msgs
    assert "`NoBitsCompressor` neither defines nor inherits" in msgs
    assert "`undocumented` is missing from" in msgs
    assert "`ghost_entry` names no registered compressor" in msgs
    assert "`no_wire` (NoWireCompressor.compress) builds no WirePayload" \
        in msgs
    assert "`OddBlockCompressor` sets block=512" in msgs
    assert len(found) == 6, found


def test_bits_good_is_clean():
    assert _findings("bits_good", rules=["bits-accounting"]) == []


# -- repo + baseline + suppressions ----------------------------------------


def test_repo_is_clean_against_committed_baseline():
    res = run_lint(REPO, baseline_path=DEFAULT_BASELINE)
    assert res.findings == [], res.findings
    assert res.stale_baseline == [], res.stale_baseline


def test_committed_baseline_is_exact():
    """Every committed baseline entry must still match a live finding —
    stale entries fail the run (the baseline can only shrink honestly)."""
    entries = json.loads(DEFAULT_BASELINE.read_text())["findings"]
    res = run_lint(REPO, baseline_path=DEFAULT_BASELINE)
    matched = {f.key for f in res.baselined}
    for e in entries:
        assert (e["rule"], e["path"], e["message"]) in matched, (
            f"stale baseline entry: {e}")
        assert e.get("justification", "").strip(), (
            f"baseline entry without justification: {e}")


def test_repo_suppressions_are_counted_and_scoped():
    """The repo's inline suppressions actually silence findings (they
    reappear when the baseline is the only escape hatch removed), and a
    suppression for rule A does not silence rule B."""
    res = run_lint(REPO, baseline_path=None)
    assert len(res.suppressed) >= 3
    rules_suppressed = {f.rule for f in res.suppressed}
    assert "pallas-contract" in rules_suppressed
    assert "jit-hazard" in rules_suppressed
    # scoping: every suppressed finding's line carries ITS rule name
    for f in res.suppressed:
        line = (REPO / f.path).read_text().splitlines()[f.line - 1]
        assert f"disable={f.rule}" in line


def test_stale_baseline_entry_fails_run(tmp_path):
    ghost = tmp_path / "baseline.json"
    write_baseline(ghost, [Finding("jit-hazard", "src/nope.py", 1,
                                   "never matches")])
    res = run_lint(REPO, baseline_path=ghost)
    assert res.stale_baseline and not res.ok


def test_baseline_absorbs_findings(tmp_path):
    """A finding written to the baseline stops being actionable."""
    bad_root = FIXTURES / "jit_bad"
    res = run_lint(bad_root, rules=["jit-hazard"], baseline_path=None)
    assert res.findings
    bl = tmp_path / "baseline.json"
    write_baseline(bl, res.findings)
    res2 = run_lint(bad_root, rules=["jit-hazard"], baseline_path=bl)
    assert res2.findings == [] and len(res2.baselined) == len(res.findings)
    assert res2.ok


# -- CLI contract ----------------------------------------------------------


def _cli(*args, cwd=REPO):
    return subprocess.run([sys.executable, "-m", "tools.lint", *args],
                          cwd=cwd, capture_output=True, text=True)


def test_cli_repo_exits_zero():
    proc = _cli()
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.parametrize("case", ["pallas_bad", "jit_bad",
                                  "refparity_bad", "bits_bad"])
def test_cli_known_bad_fixture_exits_nonzero(case):
    proc = _cli("--root", str(FIXTURES / case))
    assert proc.returncode == 1, proc.stdout + proc.stderr


def test_cli_json_output_is_machine_readable():
    proc = _cli("--root", str(FIXTURES / "jit_bad"), "--json")
    assert proc.returncode == 1
    report = json.loads(proc.stdout)
    assert report["ok"] is False
    assert {f["rule"] for f in report["findings"]} == {"jit-hazard"}
    assert all({"rule", "path", "line", "message"} <= set(f)
               for f in report["findings"])


def test_cli_unknown_rule_is_usage_error():
    proc = _cli("--rules", "no-such-rule")
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr
