"""Mixer-level correctness: Mamba-2 SSD chunked scan vs sequential
recurrence; decode-step equivalence; MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoESpec, SSMSpec
from repro.models import layers as L
from repro.models.params import materialize


def _ssd_sequential(xh, dt, A, B, C):
    """O(s) reference recurrence: h_{t} = h_{t-1}*exp(dt_t A) + dt_t B_t x_t;
    y_t = C_t h_t."""
    b, s, h, p = xh.shape
    n = B.shape[-1]
    hstate = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * A)                    # (b, h)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t],
                         xh[:, t].astype(jnp.float32),
                         B[:, t].astype(jnp.float32))
        hstate = hstate * decay[..., None, None] + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t].astype(jnp.float32),
                             hstate))
    return jnp.stack(ys, 1), hstate


@pytest.mark.parametrize("s,chunk", [(8, 4), (16, 4), (12, 12), (32, 8)])
def test_ssd_chunked_equals_sequential(s, chunk):
    b, h, p, n = 2, 3, 4, 5
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(jax.random.PRNGKey(9), (b, s, n))
    y_chunk, h_chunk = L.ssd_chunked(xh, dt, A, B, C, chunk)
    y_seq, h_seq = _ssd_sequential(xh, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq),
                               atol=1e-4, rtol=1e-4)


def test_ssm_block_decode_matches_fwd():
    """Full mamba2 block: stepping token-by-token with ssm_decode matches
    the parallel ssm_fwd outputs."""
    d, s, b = 64, 12, 2
    spec = SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=32, chunk_size=4)
    meta = L.ssm_params(d, spec)
    p = materialize(meta, jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    y_par, cache_out = L.ssm_fwd(p, spec, x)

    cache = {"conv": jnp.zeros((b, spec.d_conv - 1, 2 * d * 2 // 2 + 2 * 16)),
             "state": jnp.zeros((b, spec.num_heads(d), spec.head_dim, 16))}
    ch = 2 * d + 2 * 16   # d_inner + 2*n
    cache["conv"] = jnp.zeros((b, spec.d_conv - 1, ch))
    ys = []
    for t in range(s):
        y_t, cache = L.ssm_decode(p, spec, x[:, t:t + 1], cache)
        ys.append(y_t[:, 0])
    y_step = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_par),
                               atol=2e-4, rtol=2e-3)
    # final states agree too
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(cache_out["state"]),
                               atol=1e-4, rtol=1e-3)


def test_moe_routing_invariants():
    d = 32
    spec = MoESpec(num_experts=4, top_k=2, d_ff=64, capacity_factor=2.0)
    meta = L.moe_params(d, spec)
    p = materialize(meta, jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    y, aux = L.moe_fwd(p, spec, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    # aux loss near 1 (=E * uniform^2 * E) for near-uniform routing at init
    assert 0.5 < float(aux) < 4.0

    # capacity semantics: with tiny capacity, output magnitude shrinks
    # (tokens dropped), never NaN
    tight = MoESpec(num_experts=4, top_k=2, d_ff=64, capacity_factor=0.1)
    y2, _ = L.moe_fwd(p, tight, x)
    assert jnp.isfinite(y2).all()
    assert float(jnp.linalg.norm(y2)) <= float(jnp.linalg.norm(y)) + 1e-3


def test_moe_shared_expert_contributes():
    d = 16
    spec = MoESpec(num_experts=2, top_k=1, d_ff=32,
                   num_shared_experts=1, shared_d_ff=32)
    meta = L.moe_params(d, spec)
    p = materialize(meta, jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, d))
    y, _ = L.moe_fwd(p, spec, x)
    p0 = dict(p)
    p0["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y0, _ = L.moe_fwd(p0, spec, x)
    assert float(jnp.max(jnp.abs(y - y0))) > 1e-6
