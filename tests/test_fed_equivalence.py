"""Scan <-> shard_map driver equivalence for STATEFUL compressors.

The shard_map spatial driver threads per-client compressor state (EF
residuals under ``client_state["comp"]``, plus the ``local_adam``
persistent moments) through the MANUAL region — these tests pin it to
the ``client_mode="scan"`` reference: 3 rounds from identical seeds must
produce the same global state and the same per-client state every round.

* shared / independent top-k with error feedback: BIT-identical — the
  per-client compute is elementwise + mask selection, and the mesh
  driver's dense aggregation replays scan's exact accumulation order
  (``aggregate.ordered_weighted_sum``).
* 1-bit Adam / Efficient-Adam: identical to ~2 ulp (f32).  Their block
  L1 / min-max scales are reductions, and XLA fuses those differently
  inside the scan body vs the shard_map body, so bitwise equality is not
  guaranteed by construction; the state threading itself is exact (the
  round-0 client state matches bitwise before any reduction feeds back).

Runs in a SUBPROCESS with 8 forced host devices (this process must keep
the 1-device backend for the smoke tests), like test_mesh_integration.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

_REPO = Path(__file__).resolve().parents[1]

#: algorithm -> (FedConfig kwargs, must be bit-identical)
STATEFUL = {
    "fedadam_ssm": (dict(error_feedback=True, alpha=0.25), True),
    "fedadam_top": (dict(error_feedback=True, alpha=0.25), True),
    "onebit_adam": (dict(), False),
    "efficient_adam": (dict(), False),
}

_SUB = textwrap.dedent("""
    import json, os
    import jax, jax.numpy as jnp
    from repro import compat
    from repro.core import FedConfig, fed_init, make_fl_round
    from repro.core import comm
    from repro.core import sparsify as S
    from repro.optim import AdamHyper

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4)) * 0.1,
              "b": jnp.zeros((4,))}
    d = sum(x.size for x in jax.tree.leaves(params))
    C = 8
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, 16, 8))
    w_true = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    ys = jnp.einsum("cbi,ij->cbj", xs, w_true)
    batches = (xs, ys)

    def loss_fn(p, b):
        x, y = b
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    mesh = jax.make_mesh((8,), ("data",))
    ALGOS = json.loads(os.environ["EQUIV_ALGOS"])

    def run(mode, algo, kw, rounds=3):
        fed = FedConfig(algorithm=algo, local_epochs=2, n_clients=C,
                        adam=AdamHyper(lr=0.05), client_mode=mode,
                        client_axes=(("data",) if mode == "vmap"
                                     else None), **kw)
        rf = jax.jit(make_fl_round(fed, loss_fn))
        st = fed_init(fed, params)
        assert st.client_state is not None, algo + " is not stateful"
        hist, bits = [], None
        if mode == "vmap":
            with compat.set_mesh(mesh):
                for _ in range(rounds):
                    st, mets = rf(st, batches)
                    hist.append(st)
                bits = float(mets["uplink_bits"])
        else:
            for _ in range(rounds):
                st, mets = rf(st, batches)
                hist.append(st)
        return hist, bits

    def maxdiff(ta, tb):
        la, lb = jax.tree.leaves(ta), jax.tree.leaves(tb)
        assert len(la) == len(lb)
        md, eq = 0.0, True
        for x, y in zip(la, lb):
            assert x.shape == y.shape and x.dtype == y.dtype
            md = max(md, float(jnp.max(jnp.abs(
                x.astype(jnp.float32) - y.astype(jnp.float32)))))
            eq = eq and bool((x == y).all())
        return md, eq

    out = {}
    for algo, kw in ALGOS.items():
        hs, _ = run("scan", algo, dict(kw))
        hm, bits = run("vmap", algo, dict(kw))
        rounds = []
        for a, b in zip(hs, hm):
            gmd, geq = maxdiff((a.W, a.M, a.V), (b.W, b.M, b.V))
            cmd, ceq = maxdiff(a.client_state, b.client_state)
            rounds.append(dict(global_maxdiff=gmd, global_eq=geq,
                               cs_maxdiff=cmd, cs_eq=ceq))
        k = S.k_for(d, kw.get("alpha", 0.05))
        sizes = tuple(x.size for x in jax.tree.leaves(params))
        expect_bits = float(C * comm.bits_for(
            algo, d, k, 1, 32, sizes=sizes,
            alpha=kw.get("alpha", 0.05)))
        out[algo] = dict(rounds=rounds, uplink_bits=bits,
                         expect_bits=expect_bits)
    print("RESULT", json.dumps(out))
""")


@pytest.fixture(scope="module")
def equiv():
    """One subprocess runs every stateful algorithm (scan + mesh, 3
    rounds each); the parameterized tests below assert per algorithm."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(_REPO / "src")
    env["EQUIV_ALGOS"] = json.dumps({k: v[0] for k, v in STATEFUL.items()})
    out = subprocess.run([sys.executable, "-c", _SUB], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("algo", sorted(STATEFUL))
def test_scan_shardmap_equivalence(equiv, algo):
    bitwise = STATEFUL[algo][1]
    rounds = equiv[algo]["rounds"]
    assert len(rounds) == 3
    for r, rec in enumerate(rounds):
        if bitwise:
            assert rec["global_eq"], \
                f"{algo} round {r}: global state differs " \
                f"(max {rec['global_maxdiff']})"
            assert rec["cs_eq"], \
                f"{algo} round {r}: per-client state differs " \
                f"(max {rec['cs_maxdiff']})"
        else:
            assert rec["global_maxdiff"] <= 2e-6, (algo, r, rec)
            assert rec["cs_maxdiff"] <= 2e-6, (algo, r, rec)
    # round 0 client state is pre-aggregation-feedback: must match
    # bitwise for EVERY compressor — state threading itself is exact
    assert rounds[0]["cs_eq"], f"{algo}: round-0 client state not bitwise"


@pytest.mark.slow
@pytest.mark.parametrize("algo", sorted(STATEFUL))
def test_mesh_uplink_bits_match_comm(equiv, algo):
    """bits reported by a mesh-driver round == comm.py wire-exact count
    (``comm.bits_for(..., sizes=...)`` == 8 * WirePayload.nbytes)."""
    assert equiv[algo]["uplink_bits"] == equiv[algo]["expect_bits"], algo
