"""Packed cohort-compression pipeline: layout round-trip, parity with the
per-leaf fused path and the composed mask ops, and the launch-count
regression gate (the whole point of the packed design: TWO Pallas
launches per compress, not 4 per leaf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masks as M
from repro.core import sparsify as S
from repro.core.compressors.base import Deltas
from repro.core.compressors.topk import IndependentTopKCompressor
from repro.kernels.packed_topk.packed_topk import BLOCK_ELEMS, LANES

ALPHA = 0.05

# ragged on purpose: sub-tile leaves (< 1024 elements), exact-tile leaves,
# ND leaves, and leaves spanning many blocks
RAGGED_SHAPES = [(1,), (37,), (1023,), (1024,), (1025,), (3, 5, 7),
                 (8, 128), (8, 1024), (2000,), (50_000,)]


def _leaves(seed, shapes=RAGGED_SHAPES, dtype=jnp.float32, scale=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return [jax.random.normal(k, s).astype(dtype) * scale
            for k, s in zip(keys, shapes)]


def _trees(seed, shapes=RAGGED_SHAPES, dtype=jnp.float32):
    names = [f"l{i}" for i in range(len(shapes))]
    dW = dict(zip(names, _leaves(seed, shapes, dtype)))
    dM = dict(zip(names, _leaves(seed + 1, shapes, dtype, 0.1)))
    dV = {n: jnp.abs(v) for n, v in
          zip(names, _leaves(seed + 2, shapes, dtype, 0.01))}
    return dW, dM, dV


def _assert_tree_equal(a, b, what=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{what}: treedef mismatch"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{what} leaf {i}")


# --- layout ---------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_layout_roundtrip(dtype):
    leaves = _leaves(0, dtype=dtype)
    layout = S.plan_packed_layout(leaves)
    buf = layout.pack(leaves)
    # tile-aligned: every leaf starts on a (8, 128)-block boundary
    assert buf.shape == (layout.total // LANES, LANES)
    assert all(off % BLOCK_ELEMS == 0 for off in layout.offsets)
    assert layout.total % BLOCK_ELEMS == 0
    assert layout.seg_ids.shape == (layout.num_blocks,)
    out = layout.unpack(buf)
    for orig, back in zip(leaves, out):
        assert back.shape == orig.shape and back.dtype == orig.dtype
        np.testing.assert_array_equal(np.asarray(back), np.asarray(orig))


def test_packed_layout_padding_is_zero():
    leaves = _leaves(1)
    layout = S.plan_packed_layout(leaves)
    flat = np.asarray(layout.pack(leaves)).reshape(-1)
    used = np.zeros(layout.total, bool)
    for off, n in zip(layout.offsets, layout.sizes):
        used[off:off + n] = True
    np.testing.assert_array_equal(flat[~used], 0.0)


def test_packed_layout_groups():
    leaves = _leaves(2)
    L = len(leaves)
    per_tensor = S.plan_packed_layout(leaves)
    assert per_tensor.num_segments == L
    assert per_tensor.seg_sizes == per_tensor.sizes
    glob = S.plan_packed_layout(leaves, [0] * L)
    assert glob.num_segments == 1
    assert glob.seg_sizes == (sum(glob.sizes),)
    assert bool(jnp.all(glob.seg_ids == 0))


# --- parity: packed vs per-leaf fused vs composed mask ops ----------------


@pytest.mark.parametrize("scope", ["per_tensor", "global"])
def test_packed_bit_exact_vs_perleaf_fused(scope):
    """The tentpole guarantee: every output of the packed two-launch
    pipeline — values, wire-cast, EF residual, masks — is BITWISE the
    per-leaf fused path's."""
    dW, dM, dV = _trees(10)
    packed = S.tree_shared_compress_packed(
        None, dW, dM, dV, ALPHA, scope,
        value_dtype="bfloat16", with_residual=True)
    perleaf = S.tree_shared_compress_fused(
        None, dW, dM, dV, ALPHA, scope,
        value_dtype="bfloat16", with_residual=True, packed=False)
    for name, a, b in zip(("sW", "sM", "sV", "err", "mask"),
                          packed, perleaf):
        _assert_tree_equal(a, b, f"{scope} {name}")


@pytest.mark.parametrize("scope", ["per_tensor", "global"])
def test_packed_masks_match_tree_topk_masks(scope):
    """Packed tau selection is the same selection tree_topk_masks'
    threshold-kernel path performs, leaf for leaf."""
    dW, dM, dV = _trees(20)
    *_, mask_tree = S.tree_shared_compress_packed(
        None, dW, dM, dV, ALPHA, scope)
    composed = S.tree_topk_masks(dW, ALPHA, scope, exact=False,
                                 backend="kernel")
    _assert_tree_equal(mask_tree, composed, f"{scope} mask")


def test_packed_with_score_tree():
    """Non-ssm_w rules stream a separate score tensor; masks must follow
    the score, values the deltas."""
    dW, dM, dV = _trees(30)
    score = {k: jnp.abs(v) for k, v in dM.items()}      # ssm_m rule
    sW, sM, sV, err, mask = S.tree_shared_compress_packed(
        score, dW, dM, dV, ALPHA, "per_tensor", with_residual=True)
    composed = S.tree_topk_masks(score, ALPHA, "per_tensor", exact=False,
                                 backend="kernel")
    _assert_tree_equal(mask, composed, "score-tree mask")
    _assert_tree_equal(sW, S.tree_sparsify(dW, mask), "score-tree sW")
    _assert_tree_equal(
        err, jax.tree.map(lambda w, s: w - s, dW, sW), "score-tree err")


def test_packed_independent_matches_composed():
    dW, dM, dV = _trees(40)
    sW, sM, sV, err, (mW, mM, mV) = S.tree_independent_compress_packed(
        dW, dM, dV, ALPHA, "per_tensor", with_residual=True)
    cW, cM, cV = M.independent_masks(dW, dM, dV, ALPHA, "per_tensor",
                                     exact=False, backend="kernel")
    _assert_tree_equal(mW, cW, "independent mW")
    _assert_tree_equal(mM, cM, "independent mM")
    _assert_tree_equal(mV, cV, "independent mV")
    _assert_tree_equal(sW, S.tree_sparsify(dW, cW), "independent sW")
    _assert_tree_equal(sM, S.tree_sparsify(dM, cM), "independent sM")
    _assert_tree_equal(sV, S.tree_sparsify(dV, cV), "independent sV")
    _assert_tree_equal(
        err, jax.tree.map(lambda w, s: w - s, dW, sW), "independent err")


def test_packed_degenerate_alpha_keeps_everything():
    dW, dM, dV = _trees(50)
    sW, sM, sV, err, mask = S.tree_shared_compress_packed(
        None, dW, dM, dV, 1.0, "per_tensor", with_residual=True)
    _assert_tree_equal(sW, dW, "alpha=1 sW")
    _assert_tree_equal(sM, dM, "alpha=1 sM")
    _assert_tree_equal(sV, dV, "alpha=1 sV")
    for leaf in jax.tree_util.tree_leaves(err):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_fused_mixed_dtype_falls_back_to_perleaf():
    """Mixed-dtype trees can't share one packed buffer; the packed=True
    default must quietly take the per-leaf loop and still be correct."""
    dW, dM, dV = _trees(60, shapes=[(2000,), (8, 1024)])
    dW["l0"] = dW["l0"].astype(jnp.bfloat16)
    out = S.tree_shared_compress_fused(None, dW, dM, dV, ALPHA,
                                       "per_tensor", with_residual=True)
    ref = S.tree_shared_compress_fused(None, dW, dM, dV, ALPHA,
                                       "per_tensor", with_residual=True,
                                       packed=False)
    for a, b in zip(out, ref):
        _assert_tree_equal(a, b, "mixed-dtype fallback")


def test_independent_compressor_packed_path_matches_composed():
    """Compressor-level: the kernel backend's packed payload equals the
    composed kernel-path masks applied to the deltas (the reference
    backend's bisection tau differs by construction, so the comparison
    target is the composed KERNEL mask path)."""
    dW, dM, dV = _trees(70, shapes=[(9001,), (37,), (8, 1024)])
    deltas = Deltas(dW, dM, dV)
    comp = IndependentTopKCompressor(
        alpha=ALPHA, exact_topk=False, error_feedback=True,
        sparsify_backend="kernel")
    packed, state, _ = comp.compress(deltas, comp.init_state(deltas.W))
    cW, cM, cV = M.independent_masks(dW, dM, dV, ALPHA, "per_tensor",
                                     exact=False, backend="kernel")
    _assert_tree_equal(packed.W, S.tree_sparsify(dW, cW),
                       "independent compressor W")
    _assert_tree_equal(packed.M, S.tree_sparsify(dM, cM),
                       "independent compressor M")
    _assert_tree_equal(packed.V, S.tree_sparsify(dV, cV),
                       "independent compressor V")
    _assert_tree_equal(
        state["err"], jax.tree.map(lambda w, s: w - s, dW, packed.W),
        "independent compressor err")


@pytest.mark.parametrize("cname", ["whisper-base", "starcoder2-3b"])
def test_packed_smoke_pytree_bit_exact(monkeypatch, cname):
    """Acceptance gate on real model pytrees (smoke shapes): the packed
    pipeline is bit-identical to the per-leaf fused path AND costs at
    most two Pallas launches for the whole model."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import abstract_params, params as PM
    cfg = reduce_for_smoke(get_config(cname))
    sds = PM.abstract(abstract_params(cfg), "float32")
    leaves, treedef = jax.tree_util.tree_flatten(sds)
    keys = jax.random.split(jax.random.PRNGKey(0),
                            3 * len(leaves)).reshape(3, len(leaves), 2)
    mk = lambda row, s: jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, l.shape, jnp.float32) * s
                  for k, l in zip(row, leaves)])
    dW, dM = mk(keys[0], 1.0), mk(keys[1], 0.1)
    dV = jax.tree.map(jnp.abs, mk(keys[2], 0.01))

    counter = _count_pallas_calls(monkeypatch)
    jax.clear_caches()
    counter["n"] = 0
    packed = S.tree_shared_compress_packed(
        None, dW, dM, dV, ALPHA, "per_tensor",
        value_dtype="bfloat16", with_residual=True)
    jax.block_until_ready(packed[0])
    assert counter["n"] <= 2, f"{cname}: {counter['n']} launches"

    perleaf = S.tree_shared_compress_fused(
        None, dW, dM, dV, ALPHA, "per_tensor",
        value_dtype="bfloat16", with_residual=True, packed=False)
    for name, a, b in zip(("sW", "sM", "sV", "err", "mask"),
                          packed, perleaf):
        _assert_tree_equal(a, b, f"{cname} {name}")


# --- launch accounting ----------------------------------------------------


def _count_pallas_calls(monkeypatch):
    """Spy on pl.pallas_call at its definition module: every kernel
    module does ``from jax.experimental import pallas as pl`` and calls
    ``pl.pallas_call(...)`` through the module attribute, so patching
    the attribute intercepts every launch construction.

    Counts happen at TRACE time, so callers must ``jax.clear_caches()``
    immediately before the measured call — a jit cache hit replays the
    compiled executable without re-entering pallas_call.  For the same
    reason the count is a FLOOR on runtime launches: two same-shape
    launches inside one fresh trace region count once (e.g. the
    per-leaf selection's two count passes share one count_ge trace)."""
    import jax.experimental.pallas as pl_mod
    real = pl_mod.pallas_call
    counter = {"n": 0}

    def spy(*args, **kwargs):
        counter["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pl_mod, "pallas_call", spy)
    return counter


def _launch_shapes(n_leaves):
    # one extra 8192-element tile per leaf, so every leaf pads to a
    # DIFFERENT 2D shape and the per-leaf path can't share traces
    # across leaves; all >= 8192 so its apply stage also runs as a
    # kernel (it falls back to jnp below 8192 elements)
    return [(8192 * (i + 1) + 1,) for i in range(n_leaves)]


def test_packed_compress_is_two_launches(monkeypatch):
    """The headline contract: a >= 10-leaf pytree compresses in at most
    TWO Pallas launches on the packed path, vs >= 3 per leaf on the old
    per-leaf path (recorded here as the regression baseline; the true
    per-leaf runtime count is 4/leaf — trace-level counting merges the
    two same-shape count passes)."""
    counter = _count_pallas_calls(monkeypatch)
    shapes = _launch_shapes(12)
    dW, dM, dV = _trees(80, shapes=shapes)
    L = len(shapes)

    jax.clear_caches()
    counter["n"] = 0
    S.tree_shared_compress_packed(None, dW, dM, dV, ALPHA, "per_tensor",
                                  with_residual=True)
    packed_launches = counter["n"]
    assert packed_launches <= 2, \
        f"packed path used {packed_launches} launches (contract: <= 2)"

    jax.clear_caches()
    counter["n"] = 0
    S.tree_shared_compress_fused(None, dW, dM, dV, ALPHA, "per_tensor",
                                 with_residual=True, packed=False)
    perleaf_launches = counter["n"]
    assert perleaf_launches >= 3 * L, \
        f"per-leaf baseline launched {perleaf_launches} (< 3/leaf?)"
    assert packed_launches < perleaf_launches


def test_packed_global_scope_is_two_launches(monkeypatch):
    counter = _count_pallas_calls(monkeypatch)
    dW, dM, dV = _trees(90, shapes=_launch_shapes(10))
    jax.clear_caches()
    counter["n"] = 0
    S.tree_shared_compress_packed(None, dW, dM, dV, ALPHA, "global",
                                  with_residual=True)
    assert counter["n"] <= 2


def test_packed_independent_is_two_launches(monkeypatch):
    """Three independent masks (3L tau segments) still cost the same
    two launches — the packing, not the mask count, sets the cost."""
    counter = _count_pallas_calls(monkeypatch)
    dW, dM, dV = _trees(100, shapes=_launch_shapes(10))
    jax.clear_caches()
    counter["n"] = 0
    S.tree_independent_compress_packed(dW, dM, dV, ALPHA, "per_tensor",
                                      with_residual=True)
    assert counter["n"] <= 2
