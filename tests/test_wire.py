"""The bit-packed wire format (core/wire.py): round-trips, measured
bytes == reported bits, and transport equivalences.

The contract under test, per registered compressor:

* ``8 * WirePayload.nbytes == Compressor.wire_bits_per_client(sizes)
  == comm.bits_for(algo, ..., sizes=...)`` — the metric IS the payload.
* decode(encode(carriers)) is bitwise the dense carriers for mask and
  sign schemes, and bitwise the quantizer's own reconstruction for the
  b-bit scheme.
* the vmap wire transport (packed words crossing the client axis)
  aggregates exactly like the scan reference fold.

Property tests ride tests/_propcheck.py (hypothesis when installed,
seeded deterministic fallback otherwise): random leaf shapes with odd
tails exercise the 1024-element block padding and the 4096-element
word-group alignment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core import FedConfig, comm, compressors, fed_init, make_fl_round
from repro.core import aggregate, quantize, sparsify as S, wire
from repro.core.compressors import Deltas
from repro.optim import AdamHyper

_F32 = jnp.float32


def _tree(shapes, seed=0, scale=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {f"t{i}": jax.random.normal(k, s) * scale
            for i, (k, s) in enumerate(zip(keys, shapes))}


def _sizes(tree):
    return tuple(x.size for x in jax.tree.leaves(tree))


def _biteq(ta, tb):
    la, lb = jax.tree.leaves(ta), jax.tree.leaves(tb)
    assert len(la) == len(lb)
    return all(bool(jnp.all(a == b)) for a, b in zip(la, lb))


def _exact_mask(tree, alpha):
    return jax.tree.map(
        lambda x: S.topk_mask_exact(x, S.k_for(x.size, alpha)), tree)


@st.composite
def _shapes(draw):
    """1-3 leaves, 1-D or 2-D, sizes with odd tails (1..~1800)."""
    n = draw(st.integers(1, 3))
    out = []
    for _ in range(n):
        if draw(st.integers(0, 1)):
            out.append((draw(st.integers(1, 1800)),))
        else:
            out.append((draw(st.integers(1, 60)),
                        draw(st.integers(1, 30))))
    return tuple(out)


# ---------------------------------------------------------------------------
# Round-trip properties (random shapes, odd tails)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(_shapes(), st.floats(0.05, 0.8))
def test_shared_mask_wire_roundtrip(shapes, alpha):
    dW, dM, dV = (_tree(shapes, seed=s) for s in (0, 1, 2))
    mask = _exact_mask(dW, alpha)
    sp = lambda t: jax.tree.map(lambda x, m: x * m, t, mask)
    sW, sM, sV = sp(dW), sp(dM), sp(dV)
    cap = wire.mask_value_capacity(_sizes(dW), alpha)
    payload = wire.pack_shared_mask(sW, sM, sV, cap)
    rW, rM, rV = wire.unpack_shared_mask(payload, sW)
    assert _biteq((rW, rM, rV), (sW, sM, sV))
    # idempotence: re-encoding the decoded triple reproduces the payload
    # (the async driver's re-materialization relies on this)
    again = wire.pack_shared_mask(rW, rM, rV, cap)
    assert _biteq(again, payload)


@settings(max_examples=10, deadline=None)
@given(_shapes(), st.floats(0.05, 0.8))
def test_independent_mask_wire_roundtrip(shapes, alpha):
    trees = [_tree(shapes, seed=s) for s in (3, 4, 5)]
    sp = [jax.tree.map(lambda x, m: x * m, t, _exact_mask(t, alpha))
          for t in trees]
    cap = wire.mask_value_capacity(_sizes(trees[0]), alpha)
    payload = wire.pack_independent_mask(*sp, cap)
    out = wire.unpack_independent_mask(payload, sp[0])
    assert _biteq(out, tuple(sp))


@settings(max_examples=10, deadline=None)
@given(_shapes())
def test_sign_wire_roundtrip(shapes):
    x = _tree(shapes, seed=6)
    q = quantize.tree_sign_quant(x, wire.SCALE_BLOCK)
    payload = wire.pack_sign(q)
    out = wire.unpack_sign(payload, q)
    assert _biteq(out, q)


@settings(max_examples=10, deadline=None)
@given(_shapes(), st.sampled_from([2, 4, 8]))
def test_bbit_wire_roundtrip(shapes, bits):
    x = _tree(shapes, seed=7)
    leaves, treedef = jax.tree_util.tree_flatten(x)
    enc = [quantize.uniform_encode(v, bits, wire.SCALE_BLOCK)
           for v in leaves]
    payload = wire.pack_bbit_codes([c for c, _ in enc],
                                   [s for _, s in enc], bits)
    out = wire.unpack_bbit_codes(payload, x, bits)
    # the wire reconstructs exactly what the quantizer reconstructs
    want = jax.tree_util.tree_unflatten(treedef, [
        quantize.uniform_quant(v, bits, wire.SCALE_BLOCK) for v in leaves])
    assert _biteq(out, want)


@settings(max_examples=10, deadline=None)
@given(_shapes())
def test_dense_wire_roundtrip(shapes):
    trees = tuple(_tree(shapes, seed=s) for s in (8, 9, 10))
    payload = wire.pack_dense(trees)
    out = wire.unpack_dense(payload, trees[0])
    assert _biteq(out, trees)
    assert 8 * wire.payload_nbytes(payload) == \
        wire.dense_wire_bits(_sizes(trees[0]), 3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5000))
def test_pack_bits_1d_roundtrip(n):
    bits = (jax.random.uniform(jax.random.PRNGKey(n), (n,)) < 0.37)
    words = wire.pack_bits_1d(bits)
    assert words.dtype == jnp.uint32 and words.shape == (-(-n // 32),)
    back = wire.unpack_bits_1d(words, n)
    assert bool(jnp.all(back == bits.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# Measured bytes == reported bits, per registered compressor
# ---------------------------------------------------------------------------

_PARAMS_SHAPES = ((37, 5), (11,))


def _compress_once(algo, alpha=0.25):
    fed = FedConfig(algorithm=algo, alpha=alpha, n_clients=2)
    comp = compressors.make_compressor(fed)
    params = _tree(_PARAMS_SHAPES, seed=11, scale=0.1)
    state = comp.init_state(params)
    deltas = Deltas(_tree(_PARAMS_SHAPES, seed=12),
                    _tree(_PARAMS_SHAPES, seed=13),
                    _tree(_PARAMS_SHAPES, seed=14))
    packed, _, _ = comp.compress(deltas, state)
    return fed, comp, params, packed


@pytest.mark.parametrize("algo", compressors.available())
def test_measured_bits_equal_accounting(algo):
    """THE acceptance identity: 8 * payload.nbytes ==
    wire_bits_per_client == comm.bits_for(..., sizes=...)."""
    fed, comp, params, packed = _compress_once(algo)
    assert packed.wire is not None, f"{algo}: no wire payload at q=32"
    sizes = _sizes(params)
    wb = comp.wire_bits_per_client(sizes)
    assert wb is not None
    assert 8 * wire.payload_nbytes(packed.wire) == wb, algo
    d = sum(sizes)
    assert wb == comm.bits_for(algo, d, S.k_for(d, fed.alpha), 1, 32,
                               sizes=sizes, alpha=fed.alpha), algo


@pytest.mark.parametrize("algo", compressors.available())
def test_unpack_wire_matches_decompress(algo):
    """The wire round-trip reconstructs the dense carriers the legacy
    path would have shipped — bitwise, on every communicated plane."""
    _, comp, params, packed = _compress_once(algo)
    rec = comp.unpack_wire(packed.wire, params)
    dec = comp.decompress(packed)
    planes = {"mask_shared": ("W", "M", "V"),
              "mask_independent": ("W", "M", "V"),
              "sign": ("M",), "bbit": ("W",),
              "dense": ("W", "M", "V")[:getattr(comp, "n_tensors", 3)]}
    for p in planes[comp.wire_layout]:
        assert _biteq(getattr(rec, p), getattr(dec, p)), (algo, p)


def test_wire_bits_refused_off_contract():
    """Configs outside the layout constants get NO wire payload and an
    analytic-fallback metric instead of a silently wrong byte count."""
    fed = FedConfig(algorithm="fedadam_ssm", q_bits=16)
    comp = compressors.make_compressor(fed)
    assert comp.wire_bits_per_client((64,)) is None
    deltas = Deltas(*(_tree(((8, 8),), seed=i) for i in (1, 2, 3)))
    packed, _, _ = comp.compress(deltas, None)
    assert packed.wire is None
    with pytest.raises(ValueError):
        comm.bits_for("fedadam_ssm", 64, 3, 1, 16, sizes=(64,), alpha=0.05)


# ---------------------------------------------------------------------------
# Transport equivalences
# ---------------------------------------------------------------------------


def test_wire_gather_sum_matches_scan_fold():
    """The vmap wire transport's decode-fold is bitwise the scan
    reference accumulation of the decoded carriers."""
    fed = FedConfig(algorithm="fedadam_ssm", alpha=0.25, n_clients=3)
    comp = compressors.make_compressor(fed)
    params = _tree(_PARAMS_SHAPES, seed=15, scale=0.1)
    payloads, triples = [], []
    for c in range(3):
        deltas = Deltas(_tree(_PARAMS_SHAPES, seed=20 + c),
                        _tree(_PARAMS_SHAPES, seed=30 + c),
                        _tree(_PARAMS_SHAPES, seed=40 + c))
        packed, _, _ = comp.compress(deltas, None)
        payloads.append(packed.wire)
        triples.append(comp.unpack_wire(packed.wire, params))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *payloads)
    weights = jnp.asarray([1.0, 2.0, 0.5], _F32)
    aW, aM, aV = aggregate.wire_gather_sum(comp, stacked, params, weights)
    for plane, want in zip(
            (aW, aM, aV),
            (aggregate.ordered_weighted_sum(
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[t[i] for t in triples]), weights)
             for i in range(3))):
        assert _biteq(plane, want)


@pytest.mark.parametrize("algo", ["fedadam_ssm", "fedadam_top",
                                  "efficient_adam"])
def test_vmap_wire_transport_matches_scan(algo):
    """3 rounds, scan driver vs vmap driver over the wire transport
    (packed words crossing the client axis): same server state, same
    wire-exact uplink_bits."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4)) * 0.1,
              "b": jnp.zeros((4,))}
    C = 4
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, 16, 8))
    ys = jnp.einsum("cbi,ij->cbj", xs,
                    jax.random.normal(jax.random.PRNGKey(2), (8, 4)))

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    def run(mode, agg):
        fed = FedConfig(algorithm=algo, alpha=0.3, local_epochs=2,
                        n_clients=C, adam=AdamHyper(lr=0.05),
                        client_mode=mode, aggregate=agg)
        rf = jax.jit(make_fl_round(fed, loss_fn))
        st = fed_init(fed, params)
        for _ in range(3):
            st, mets = rf(st, (xs, ys))
        return st, float(mets["uplink_bits"])

    st_s, bits_s = run("scan", "dense")
    st_w, bits_w = run("vmap", "sparse_gather")
    assert bits_s == bits_w
    sizes = tuple(x.size for x in jax.tree.leaves(params))
    comp = compressors.make_compressor(
        FedConfig(algorithm=algo, alpha=0.3, n_clients=C))
    assert bits_w == C * comp.wire_bits_per_client(sizes)
    for a, b in zip(jax.tree.leaves(st_s.W), jax.tree.leaves(st_w.W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# Accounting boundary fix
# ---------------------------------------------------------------------------


def test_ceil_log2_boundaries():
    """d <= 1 needs ZERO index bits — the old max(2, d) clamp billed 1
    bit for single-slot index sets."""
    assert comm._ceil_log2(0) == 0
    assert comm._ceil_log2(1) == 0
    assert comm._ceil_log2(2) == 1
    assert comm._ceil_log2(3) == 2
    assert comm._ceil_log2(4) == 2
    assert comm._ceil_log2(5) == 3
    # the degenerate 1-element tree: index representation is pure values
    assert comm.bits_fedadam_ssm(1, 1, 1, q=32) == min(1 * (3 + 1), 3) * 32
