"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step on CPU with shape + finiteness
asserts.  Full configs are exercised via the dry-run only."""
import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ASSIGNED_ARCHS, get_config, reduce_for_smoke)
from repro.core import FedConfig, fed_init, make_fl_round
from repro.models import (cache_meta, decode_step, init_params, loss_fn,
                          materialize)
from repro.optim import AdamHyper


def _inputs(cfg, b=2, s=64, key=0):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                                cfg.vocab_size)
    kw = {}
    if cfg.stub_frontend:
        n = cfg.encoder.src_len if cfg.encoder is not None else \
            min(cfg.stub_frontend_tokens, 16)
        n = min(n, 64)
        kw["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (b, max(n, 8), cfg.d_model),
            jnp.float32)
    return tokens, kw


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_grad(arch):
    cfg = reduce_for_smoke(get_config(arch))
    assert cfg.d_model <= 512 and cfg.pattern_repeats <= 2
    for spec in cfg.layer_pattern:
        if spec.moe:
            assert spec.moe.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg)
    val, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, **kw)))(params)
    assert jnp.isfinite(val), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_fl_train_step(arch):
    """One FedAdam-SSM round on the reduced config: loss finite, params
    move, W/M/V updated."""
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    C = 2
    tokens = jax.random.randint(jax.random.PRNGKey(1), (C, 2, 48), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.stub_frontend:
        n = cfg.encoder.src_len if cfg.encoder is not None else \
            min(cfg.stub_frontend_tokens, 16)
        n = min(max(n, 8), 64)
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (C, 2, n, cfg.d_model), jnp.float32)

    fed = FedConfig(algorithm="fedadam_ssm", alpha=0.1, local_epochs=2,
                    n_clients=C, adam=AdamHyper(lr=1e-3))

    def loss(p, b):
        return loss_fn(cfg, p, b["tokens"],
                       frontend_embeds=b.get("embeds"), remat="none")

    rf = jax.jit(make_fl_round(fed, loss))
    st = fed_init(fed, params)
    st2, mets = rf(st, batch)
    assert jnp.isfinite(mets["loss"]).all()
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(st.W), jax.tree.leaves(st2.W)))
    assert moved
    m_norm = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
                 for x in jax.tree.leaves(st2.M))
    assert m_norm > 0    # moments aggregated (the paper's key difference)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_decode_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    seq = 64
    caches = materialize(cache_meta(cfg, 2, seq), jax.random.PRNGKey(1))
    step = jax.jit(functools.partial(decode_step, cfg, seq_len=seq))
    tok = jnp.zeros((2,), jnp.int32)
    logits, caches = step(params, caches, jnp.int32(0), tok)
    assert logits.shape == (2, cfg.padded_vocab)
    logits, caches = step(params, caches, jnp.int32(1), tok)
    assert bool(jnp.isfinite(logits).all())
