"""Roofline model: three terms from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * 197e12)            [bf16 v5e peak]
    memory     = HLO_bytes / (chips * 819e9)             [HBM bandwidth]
    collective = collective_bytes / (chips * 3 * 50e9)   [3 usable ICI links]

``cost_analysis()`` provides FLOPs / bytes-accessed for the *whole program*
(global view — we divide by chip count).  Collective bytes are NOT in
cost_analysis: we parse the post-SPMD optimized HLO (``compiled.as_text()``)
and sum the output-shape bytes of every collective op, classified by kind.

Caveat (recorded with every row): XLA's CPU-backend cost analysis counts a
``while`` body once; our steps scan over layer-repeats and local epochs, so
we scale HLO FLOPs by the known static trip counts where XLA didn't
(detected by comparing against the analytic floor).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# v5e hardware constants (per chip) — per the brief
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ICI_LINKS = 3                # usable links per chip in a 2D torus (approx)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]?[a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape literal like 'bf16[16,1024]' ('' dims = scalar)."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def _line_result_bytes(line: str) -> int:
    """Sum the bytes of the result shape(s) of an HLO instruction line:
    ``%x = f32[8]{0} op(...)`` or tuple ``%x = (f32[8], s32[8]) op(...)``.
    The shape literal(s) sit between '= ' and the op name."""
    eq = line.find("= ")
    if eq < 0:
        return 0
    rest = line[eq + 2:]
    # cut at the op-name call site: first '(' that follows the shape part.
    # Shapes may themselves contain '(' only in tuple form at the start.
    if rest.startswith("("):
        end = rest.find(")")
        shapes = rest[1:end]
    else:
        shapes = rest.split(" ", 1)[0]
    return sum(_shape_bytes(s.group(0)) for s in _SHAPE_RE.finditer(shapes))


def _loop_depth(line: str) -> int:
    """Nesting depth of the instruction = number of enclosing while loops,
    read from the op_name metadata (jax scan bodies show as /while/body/)."""
    m = re.search(r'op_name="([^"]*)"', line)
    if not m:
        return 0
    return m.group(1).count("/while/body")


def collective_bytes(hlo_text: str, loop_trips=()) -> Dict[str, int]:
    """Per-collective-kind total result bytes in the optimized HLO.

    ``loop_trips``: static trip counts of the scan nesting, outermost first
    (e.g. train: [virtual_clients, local_epochs, repeats, group, chunks]).
    A collective at while-nesting depth d is counted prod(loop_trips[:d])
    times — XLA prints each loop body once.  Both raw (static) and
    trip-scaled totals are returned.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    raw: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        head = ls.split("(")[0]
        if "fusion" in head:
            continue
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(-start)?\(", ls) and "= " in ls:
                b = _line_result_bytes(ls)
                depth = _loop_depth(ls)
                mult = 1
                for t in loop_trips[:depth]:
                    mult *= t
                raw[kind] += b
                out[kind] += b * mult
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["total_static"] = sum(raw[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float           # analytic 6*N_active*D (train) etc.
    flops_scale: float = 1.0     # scan trip-count correction applied

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_LINKS * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> Dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh,
            chips=self.chips,
            hlo_flops=self.hlo_flops, hlo_bytes=self.hlo_bytes,
            coll_bytes=self.coll_bytes, model_flops=self.model_flops,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_ratio=self.useful_ratio, flops_scale=self.flops_scale,
        )


def analytic_model_flops(cfg, shape_kind: str, seq_len: int,
                         global_batch: int, local_epochs: int = 1,
                         n_virtual_clients: int = 1) -> float:
    """6*N_active*tokens for a train round (fwd+bwd over L epochs and
    virtual clients), 2*N_active per generated token for decode."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens * local_epochs * n_virtual_clients
    if shape_kind == "prefill":
        return 2.0 * n_active * seq_len * global_batch
    # decode: one token per sequence in the batch
    return 2.0 * n_active * global_batch
