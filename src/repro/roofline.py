"""Roofline model: three terms from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * 197e12)            [bf16 v5e peak]
    memory     = HLO_bytes / (chips * 819e9)             [HBM bandwidth]
    collective = collective_bytes / (chips * 3 * 50e9)   [3 usable ICI links]

``cost_analysis()`` provides FLOPs / bytes-accessed for the *whole program*
(global view — we divide by chip count).  Collective bytes are NOT in
cost_analysis: we parse the post-SPMD optimized HLO (``compiled.as_text()``)
and sum the output-shape bytes of every collective op, classified by kind.

Caveat (recorded with every row): XLA's CPU-backend cost analysis counts a
``while`` body once; our steps scan over layer-repeats and local epochs, so
we scale HLO FLOPs by the known static trip counts where XLA didn't
(detected by comparing against the analytic floor).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# v5e hardware constants (per chip) — per the brief
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ICI_LINKS = 3                # usable links per chip in a 2D torus (approx)

# ---------------------------------------------------------------------------
# Analytic HBM byte models of the compression hot path.
#
# Single source of truth (docs/benchmarks.md §4): the benchmark suites
# (benchmarks/kernel_bench.py, benchmarks/compress_bench.py) derive every
# ``bytes_moved`` / GB/s figure from THESE helpers, and any roofline
# memory-term projection of the compress step divides the same numbers by
# HBM_BW — so benchmark bandwidth and roofline projections cannot drift.
# ``n`` is elements, ``itemsize`` the carrier width (4 = f32 wire).
# ---------------------------------------------------------------------------

#: Bisection iterations of core/sparsify.topk_mask_threshold (reference).
BISECT_ITERS = 24


def selection_bytes(n: int, itemsize: int = 4) -> int:
    """Per-leaf 3-pass streaming tau selection (kernels/topk_mask):
    absmax + two count passes, each ONE read of x."""
    return 3 * n * itemsize


def fused_apply_bytes(n: int, itemsize: int = 4) -> int:
    """Fused ssm_apply_ef: read dW/dM/dV once, write sW/sM/sV + residual
    (4th output) once — 3 reads + 4 writes."""
    return 7 * n * itemsize


def packed_select_bytes(n: int, itemsize: int = 4) -> int:
    """Packed cohort selection (kernels/packed_topk): the jnp absmax
    reduction (1 read) + the segmented-histogram launch (1 read); the
    refine counts ride in the apply launch, so selection's own traffic
    drops from 3 passes to 2."""
    return 2 * n * itemsize


def packed_apply_bytes(n: int, itemsize: int = 4) -> int:
    """Packed two-sweep apply launch: sweep 0 re-reads the score stream
    for the refine counts (1 read), sweep 1 streams dW/dM/dV (3 reads)
    and writes sW/sM/sV + residual (4 writes)."""
    return 8 * n * itemsize


def composed_compress_bytes(n: int, itemsize: int = 4,
                            bisect_iters: int = BISECT_ITERS) -> int:
    """Reference threshold compress: absmax + ``bisect_iters`` bisection
    count passes (1 read each), 3 mask-apply rounds (read + write), EF
    residual subtract (2 reads + 1 write)."""
    return (1 + bisect_iters + 6 + 3) * n * itemsize


def fused_compress_bytes(n: int, itemsize: int = 4) -> int:
    """Per-leaf kernel pipeline end to end: 3-pass selection + one fused
    apply/cast/residual pass."""
    return selection_bytes(n, itemsize) + fused_apply_bytes(n, itemsize)


def packed_compress_bytes(n: int, itemsize: int = 4) -> int:
    """Packed pipeline end to end (2 launches): histogram selection +
    two-sweep apply.  Deliberately the SAME 10n total as
    :func:`fused_compress_bytes` — the packed win is launch count
    (2 per cohort vs 4 per leaf) and pass fusion, not HBM traffic; the
    bandwidth-bound asymptote is identical (docs/kernels.md)."""
    return packed_select_bytes(n, itemsize) + packed_apply_bytes(n, itemsize)


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]?[a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape literal like 'bf16[16,1024]' ('' dims = scalar)."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def _line_result_bytes(line: str) -> int:
    """Sum the bytes of the result shape(s) of an HLO instruction line:
    ``%x = f32[8]{0} op(...)`` or tuple ``%x = (f32[8], s32[8]) op(...)``.
    The shape literal(s) sit between '= ' and the op name."""
    eq = line.find("= ")
    if eq < 0:
        return 0
    rest = line[eq + 2:]
    # cut at the op-name call site: first '(' that follows the shape part.
    # Shapes may themselves contain '(' only in tuple form at the start.
    if rest.startswith("("):
        end = rest.find(")")
        shapes = rest[1:end]
    else:
        shapes = rest.split(" ", 1)[0]
    return sum(_shape_bytes(s.group(0)) for s in _SHAPE_RE.finditer(shapes))


def _loop_depth(line: str) -> int:
    """Nesting depth of the instruction = number of enclosing while loops,
    read from the op_name metadata (jax scan bodies show as /while/body/)."""
    m = re.search(r'op_name="([^"]*)"', line)
    if not m:
        return 0
    return m.group(1).count("/while/body")


def collective_bytes(hlo_text: str, loop_trips=()) -> Dict[str, int]:
    """Per-collective-kind total result bytes in the optimized HLO.

    ``loop_trips``: static trip counts of the scan nesting, outermost first
    (e.g. train: [virtual_clients, local_epochs, repeats, group, chunks]).
    A collective at while-nesting depth d is counted prod(loop_trips[:d])
    times — XLA prints each loop body once.  Both raw (static) and
    trip-scaled totals are returned.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    raw: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        head = ls.split("(")[0]
        if "fusion" in head:
            continue
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(-start)?\(", ls) and "= " in ls:
                b = _line_result_bytes(ls)
                depth = _loop_depth(ls)
                mult = 1
                for t in loop_trips[:depth]:
                    mult *= t
                raw[kind] += b
                out[kind] += b * mult
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["total_static"] = sum(raw[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float           # analytic 6*N_active*D (train) etc.
    flops_scale: float = 1.0     # scan trip-count correction applied

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_LINKS * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> Dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh,
            chips=self.chips,
            hlo_flops=self.hlo_flops, hlo_bytes=self.hlo_bytes,
            coll_bytes=self.coll_bytes, model_flops=self.model_flops,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_ratio=self.useful_ratio, flops_scale=self.flops_scale,
        )


def analytic_model_flops(cfg, shape_kind: str, seq_len: int,
                         global_batch: int, local_epochs: int = 1,
                         n_virtual_clients: int = 1) -> float:
    """6*N_active*tokens for a train round (fwd+bwd over L epochs and
    virtual clients), 2*N_active per generated token for decode."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens * local_epochs * n_virtual_clients
    if shape_kind == "prefill":
        return 2.0 * n_active * seq_len * global_batch
    # decode: one token per sequence in the batch
    return 2.0 * n_active * global_batch
