"""jax version-compatibility shims for the mesh surface.

The production drivers target the jax >= 0.6 top-level API:
``jax.shard_map`` (mesh inferred from context, ``axis_names`` selects the
MANUAL axes, ``check_vma``) and the ``jax.set_mesh`` context.  The pinned
container jax (0.4.37) predates both — it only has
``jax.experimental.shard_map.shard_map`` (explicit mesh, ``auto`` is the
complement of the manual set, ``check_rep``) and rejects bare
``PartitionSpec`` trees in ``jit`` shardings.

Every mesh entry point in this repo goes through this module so the same
source runs on both APIs:

* :func:`shard_map`   — new-style signature, translated for old jax.
* :func:`set_mesh`    — ``jax.set_mesh`` when present, else a context
  manager that records the mesh (for :func:`active_mesh`) and enters the
  legacy ``Mesh`` context.
* :func:`jit`         — ``jax.jit`` with ``in_shardings``/``out_shardings``
  given as ``PartitionSpec`` pytrees; on old jax the specs are resolved
  against the active mesh into ``NamedSharding`` first.

One behavioural shim rides along: 0.4.x GSPMD hard-crashes
(``Check failed: sharding.IsManualSubgroup()``) lowering a ``lax.scan``
that consumes a scanned-over operand inside a *partial-auto* shard_map
region — the exact shape of ``round_shardmap``'s MANUAL-over-clients /
auto-over-model body around the transformer's stacked-layer scan.  The
shardy partitioner lowers it correctly, so :func:`set_mesh` flips
``jax_use_shardy_partitioner`` on when it activates a multi-axis mesh on
old jax.  Opt out with ``REPRO_PARTITIONER=gspmd`` (single-axis client
meshes never have auto axes and keep the default partitioner).

See docs/ARCHITECTURE.md §"Mesh compat" and tests/test_mesh_integration.py
(which exercises both drivers through these shims on whatever jax is
installed).
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

#: True when the installed jax exposes the >= 0.6 top-level mesh API.
HAS_NEW_MESH_API = hasattr(jax, "shard_map") and hasattr(jax, "set_mesh")

_local = threading.local()


def _mesh_stack():
    if not hasattr(_local, "meshes"):
        _local.meshes = []
    return _local.meshes


def active_mesh() -> Optional[Mesh]:
    """The innermost mesh entered via :func:`set_mesh` (old-jax path).
    ``None`` when no compat mesh context is active."""
    stack = _mesh_stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def _compat_set_mesh(mesh: Mesh):
    _mesh_stack().append(mesh)
    try:
        # the legacy global-mesh context: harmless, and lets library code
        # that consults the pre-0.6 thread-resources mesh agree with us
        with mesh:
            yield mesh
    finally:
        _mesh_stack().pop()


def _maybe_enable_shardy(mesh: Mesh) -> None:
    """Old-jax GSPMD cannot lower scan-over-stacked-operands inside a
    partial-auto shard_map region (XLA ``IsManualSubgroup`` check
    failure, regardless of operand sharding); shardy can.  Partial-auto
    only arises on meshes with axes beyond the client axes, so flip the
    partitioner exactly then.  ``REPRO_PARTITIONER=gspmd`` opts out."""
    if len(mesh.axis_names) <= 1:
        return
    if os.environ.get("REPRO_PARTITIONER", "").lower() == "gspmd":
        return
    if not jax.config.jax_use_shardy_partitioner:
        jax.config.update("jax_use_shardy_partitioner", True)


def set_mesh(mesh: Mesh):
    """``jax.set_mesh(mesh)`` on new jax; a stand-in context manager on
    old jax.  Always used as ``with set_mesh(mesh): ...``."""
    if HAS_NEW_MESH_API:
        return jax.set_mesh(mesh)
    _maybe_enable_shardy(mesh)
    return _compat_set_mesh(mesh)


def shard_map(f, mesh: Optional[Mesh] = None, *, in_specs, out_specs,
              axis_names=None, check_vma: bool = False):
    """New-style ``jax.shard_map`` signature on any jax.

    ``axis_names`` is the set of mesh axes the body is MANUAL over
    (``None`` = all of them); on old jax it is translated into the
    complementary ``auto`` set and ``check_vma`` into ``check_rep``.
    When ``mesh`` is omitted on old jax it is taken from the enclosing
    :func:`set_mesh` context (new jax resolves the context itself).
    """
    if HAS_NEW_MESH_API:
        kwargs: dict = dict(in_specs=in_specs, out_specs=out_specs,
                            check_vma=check_vma)
        if mesh is not None:
            kwargs["mesh"] = mesh
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map
    m = mesh if mesh is not None else active_mesh()
    if m is None:
        raise ValueError(
            "compat.shard_map on jax %s needs a concrete mesh: pass mesh= "
            "or enter repro.compat.set_mesh(mesh)" % jax.__version__)
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(m.axis_names) - frozenset(axis_names)
    return _shard_map(f, m, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def _resolve_shardings(tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec leaves -> NamedSharding(mesh, spec); None and real
    Shardings pass through (None subtrees mean "unconstrained", exactly
    as on new jax)."""
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp)
        if isinstance(sp, PartitionSpec) else sp,
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


def jit(fn, *, in_shardings=None, out_shardings=None, mesh=None, **kw):
    """``jax.jit`` accepting ``PartitionSpec`` pytrees for the shardings
    on any jax.  On new jax the specs pass straight through (resolved by
    the ``jax.set_mesh`` context); on old jax they are resolved into
    ``NamedSharding`` against ``mesh`` (default: the active compat
    mesh) before ``jax.jit`` sees them."""
    if not HAS_NEW_MESH_API:
        m = mesh if mesh is not None else active_mesh()
        if m is None:
            raise ValueError(
                "compat.jit needs a mesh for PartitionSpec shardings on "
                "jax %s: pass mesh= or enter set_mesh" % jax.__version__)
        in_shardings = _resolve_shardings(in_shardings, m)
        out_shardings = _resolve_shardings(out_shardings, m)
    return jax.jit(fn, in_shardings=in_shardings,
                   out_shardings=out_shardings, **kw)
