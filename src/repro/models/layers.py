"""Model layers: norms, RoPE, attention (GQA / MLA / windowed), MLP, MoE,
Mamba-2 (SSD) — pure JAX, shardable, scan-friendly.

Conventions
-----------
* every layer has ``<name>_params(cfg-ish) -> pytree[P]`` and a forward fn
  taking the materialized pytree;
* activations are (batch, seq, d_model) in the model dtype; softmax /
  normalization statistics accumulate in float32;
* training attention uses an online-softmax scan over KV chunks so the
  lowered HLO never materializes a (seq x seq) score tensor;
* decode functions process exactly one new token against a cache.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import AttentionSpec, LayerSpec, MoESpec, SSMSpec
from repro.models.params import P

NEG_INF = -1e9          # finite mask value (see online-softmax notes)
_F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_params(d: int):
    return {"scale": P((d,), ("embed",), init="ones", dtype="float32")}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(_F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps) * p["scale"].astype(_F32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., seq, heads..., head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=_F32) / half)
    ang = positions.astype(_F32)[..., None] * freqs          # (..., seq, half)
    # insert singleton dims for the head axes between seq and head_dim
    extra = x.ndim - positions.ndim - 1
    for _ in range(extra):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — online-softmax over KV chunks (training / prefill)
# ---------------------------------------------------------------------------


def _chunk_mask(qpos, kpos, *, causal: bool, window: Optional[int],
                kv_valid_len=None):
    """qpos: (sq,), kpos: (L,) -> bool (sq, L)."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    if kv_valid_len is not None:
        m &= kpos[None, :] < kv_valid_len
    return m


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      kv_valid_len=None, chunk=1024):
    """Online-softmax attention.

    q: (b, sq, nkv, g, hd) — GQA groups g = heads/kv_heads folded explicitly.
    k, v: (b, skv, nkv, hd).
    Returns (b, sq, nkv, g, hd) in q.dtype.
    """
    b, sq, nkv, g, hd = q.shape
    skv = k.shape[1]
    if skv % chunk:
        chunk = skv                                   # single-shot fallback
    nchunks = skv // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(_F32) * scale
    qpos = q_offset + jnp.arange(sq)

    ks = k.reshape(b, nchunks, chunk, nkv, hd)
    vs = v.reshape(b, nchunks, chunk, nkv, hd)

    @jax.checkpoint
    def body(carry, inp):
        # rematerialized in backward: the (b, nkv, g, sq, chunk) score
        # tensor is the single largest training activation — recomputing it
        # costs one extra QK^T einsum per chunk and saves its storage.
        m, l, acc = carry                              # m,l: (b,nkv,g,sq)
        kc, vc, j = inp                                # kc: (b,chunk,nkv,hd)
        s = jnp.einsum("bqkgh,bckh->bkgqc", qf, kc.astype(_F32))
        kpos = j * chunk + jnp.arange(chunk)
        mask = _chunk_mask(qpos, kpos, causal=causal, window=window,
                           kv_valid_len=kv_valid_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        upd = jnp.einsum("bkgqc,bckh->bkgqh", p, vc.astype(_F32))
        acc = acc * alpha[..., None] + upd
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, nkv, g, sq), -1e30, _F32)
    l0 = jnp.zeros((b, nkv, g, sq), _F32)
    a0 = jnp.zeros((b, nkv, g, sq, hd), _F32)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), jnp.arange(nchunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)      # (b, sq, nkv, g, hd)


def decode_attention(q, k_cache, v_cache, *, pos, window=None,
                     ring: bool = False):
    """One-token attention against a cache.

    q: (b, nkv, g, hd); caches: (b, S, nkv, hd); pos: scalar int32 — index of
    the *current* token (already written into the cache).
    ring=True: cache is a ring buffer of size S=window written at t % S.
    """
    b, S, nkv, hd = k_cache.shape
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bkgh,bskh->bkgs", q.astype(_F32) * scale,
                   k_cache.astype(_F32))
    slots = jnp.arange(S)
    if ring:
        # slot s holds global position pos - ((pos - s) mod S); valid iff >= 0
        gpos = pos - jnp.mod(pos - slots, S)
        valid = gpos >= 0
    else:
        valid = slots <= pos
        if window is not None:
            valid &= slots > pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    s = s - s.max(-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(_F32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def attention_params(d: int, a: AttentionSpec, cross: bool = False):
    if a.is_mla:
        return mla_params(d, a)
    p = {
        "wq": P((d, a.num_heads, a.head_dim), ("embed", "heads", "head_dim"),
                init="scaled", fan_in=d),
        "wk": P((d, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", "head_dim"),
                init="scaled", fan_in=d),
        "wv": P((d, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", "head_dim"),
                init="scaled", fan_in=d),
        "wo": P((a.num_heads, a.head_dim, d), ("heads", "head_dim", "embed"),
                init="scaled", fan_in=a.num_heads * a.head_dim),
    }
    return p


def attention_fwd(p, a: AttentionSpec, x, *, positions, window_override=None,
                  kv=None, kv_valid_len=None, chunk=1024):
    """Training/prefill forward.  x: (b, s, d).  kv: optional (b, skv, d)
    source for cross-attention (encoder states); causal only for self-attn.
    Returns (out, (k, v)) — k/v returned for cache priming."""
    if a.is_mla:
        return mla_fwd(p, a, x, positions=positions, chunk=chunk)
    b, s, _ = x.shape
    src = x if kv is None else kv
    cross = kv is not None
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if not cross:
        q = rope(q, positions, a.rope_theta)
        k = rope(k, positions, a.rope_theta)
    g = a.num_heads // a.num_kv_heads
    qg = q.reshape(b, s, a.num_kv_heads, g, a.head_dim)
    window = a.window if window_override is None else window_override
    out = chunked_attention(qg, k, v, causal=not cross, window=window,
                            kv_valid_len=kv_valid_len, chunk=chunk)
    out = out.reshape(b, s, a.num_heads * a.head_dim)
    wo = p["wo"].reshape(a.num_heads * a.head_dim, -1)
    return jnp.einsum("bsk,kd->bsd", out, wo), (k, v)


def attention_decode(p, a: AttentionSpec, x, cache, *, pos,
                     window_override=None, ring=False):
    """x: (b, 1, d); cache: dict(k,v) (b, S, nkv, hd).  Writes the current
    token into the cache (at pos, or pos % S for ring) then attends."""
    if a.is_mla:
        return mla_decode(p, a, x, cache, pos=pos)
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]      # (b, H, hd)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])[:, 0]
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])[:, 0]
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = rope(q[:, None], posv, a.rope_theta)[:, 0]
    k = rope(k[:, None], posv, a.rope_theta)[:, 0]
    S = cache["k"].shape[1]
    slot = jnp.mod(pos, S) if ring else pos
    k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k[:, None], slot, 1)
    v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v[:, None], slot, 1)
    g = a.num_heads // a.num_kv_heads
    qg = q.reshape(b, a.num_kv_heads, g, a.head_dim)
    window = a.window if window_override is None else window_override
    out = decode_attention(qg, k_cache, v_cache, pos=pos,
                           window=None if ring else window, ring=ring)
    out = out.reshape(b, 1, a.num_heads * a.head_dim)
    wo = p["wo"].reshape(a.num_heads * a.head_dim, -1)
    y = jnp.einsum("bsk,kd->bsd", out, wo)
    return y, {"k": k_cache, "v": v_cache}


def attention_cache(a: AttentionSpec, batch: int, cache_len: int, dtype):
    if a.is_mla:
        return {"ckv": P((batch, cache_len, a.kv_lora_rank),
                         ("batch", "kv_seq", "kv_lora"), init="zeros",
                         dtype=dtype)}
    shape = (batch, cache_len, a.num_kv_heads, a.head_dim)
    axes = ("batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": P(shape, axes, init="zeros", dtype=dtype),
            "v": P(shape, axes, init="zeros", dtype=dtype)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_params(d: int, a: AttentionSpec):
    r = a.kv_lora_rank
    p = {
        "wq": P((d, a.num_heads, a.head_dim), ("embed", "heads", "head_dim"),
                init="scaled", fan_in=d),
        "w_dkv": P((d, r), ("embed", "kv_lora"), init="scaled", fan_in=d),
        "w_uk": P((r, a.num_heads, a.head_dim), ("kv_lora", "heads", "head_dim"),
                  init="scaled", fan_in=r),
        "w_uv": P((r, a.num_heads, a.head_dim), ("kv_lora", "heads", "head_dim"),
                  init="scaled", fan_in=r),
        "wo": P((a.num_heads, a.head_dim, d), ("heads", "head_dim", "embed"),
                init="scaled", fan_in=a.num_heads * a.head_dim),
    }
    return p


def mla_fwd(p, a: AttentionSpec, x, *, positions, chunk=1024):
    """Training: expand the latent to full K/V (naive form).

    NoPE convention (no rotary on the MLA path) so the training math is
    *identical* to the absorbed decode path — the released DeepSeek models
    use a decoupled rope/nope head split instead; see mla_decode notes."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])         # (b, s, r)
    k = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
    qg = q.reshape(b, s, a.num_heads, 1, a.head_dim)        # g=1 per head
    out = chunked_attention(qg, k, v, causal=True, chunk=chunk)
    out = out.reshape(b, s, a.num_heads * a.head_dim)
    wo = p["wo"].reshape(a.num_heads * a.head_dim, -1)
    return jnp.einsum("bsk,kd->bsd", out, wo), (ckv,)


def mla_decode(p, a: AttentionSpec, x, cache, *, pos):
    """Decode with the *absorbed* form: scores and context live in the
    latent space, so the cache stores only c_kv (b, S, r).

    NOTE on RoPE: the released DeepSeek models use a decoupled rope/nope
    head split so that rotation commutes with absorption.  We adopt the
    simpler NoPE-in-latent convention for the absorbed path (rope applied
    to q only contributes a head-invariant rotation that we drop), which
    keeps the cache fully compressed; the training path applies full rope.
    Documented in docs/ARCHITECTURE.md §5 as a family-faithful
    simplification.
    """
    b = x.shape[0]
    r = a.kv_lora_rank
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]       # (b, H, hd)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])          # (b, 1, r)
    cache_ckv = lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, 1)
    # absorb: q_lat[h] = w_uk[.,h,:]^T q[h]  -> (b, H, r)
    q_lat = jnp.einsum("bhk,rhk->bhr", q.astype(_F32),
                       p["w_uk"].astype(_F32))
    scale = 1.0 / math.sqrt(a.head_dim)
    s = jnp.einsum("bhr,bsr->bhs", q_lat * scale, cache_ckv.astype(_F32))
    valid = jnp.arange(cache_ckv.shape[1]) <= pos
    s = jnp.where(valid[None, None], s, NEG_INF)
    s = s - s.max(-1, keepdims=True)
    pr = jnp.exp(s)
    pr = pr / jnp.maximum(pr.sum(-1, keepdims=True), 1e-30)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", pr, cache_ckv.astype(_F32))
    out = jnp.einsum("bhr,rhk->bhk", ctx_lat, p["w_uv"].astype(_F32))
    out = out.reshape(b, 1, a.num_heads * a.head_dim).astype(x.dtype)
    wo = p["wo"].reshape(a.num_heads * a.head_dim, -1)
    return jnp.einsum("bsk,kd->bsd", out, wo), {"ckv": cache_ckv}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_params(d: int, d_ff: int, gated: bool = True):
    if gated:
        return {
            "w_gate": P((d, d_ff), ("embed", "mlp"), init="scaled", fan_in=d),
            "w_up": P((d, d_ff), ("embed", "mlp"), init="scaled", fan_in=d),
            "w_down": P((d_ff, d), ("mlp", "embed"), init="scaled", fan_in=d_ff),
        }
    return {
        "w_up": P((d, d_ff), ("embed", "mlp"), init="scaled", fan_in=d),
        "w_down": P((d_ff, d), ("mlp", "embed"), init="scaled", fan_in=d_ff),
    }


def mlp_fwd(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(gate) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# MoE — token-choice top-k with capacity, sort-free cumsum dispatch
# ---------------------------------------------------------------------------


def moe_params(d: int, m: MoESpec):
    p = {
        "router": P((d, m.num_experts), ("embed", "experts"),
                    init="scaled", fan_in=d, dtype="float32"),
        "w_gate": P((m.num_experts, d, m.d_ff), ("experts", "embed", "mlp"),
                    init="scaled", fan_in=d),
        "w_up": P((m.num_experts, d, m.d_ff), ("experts", "embed", "mlp"),
                  init="scaled", fan_in=d),
        "w_down": P((m.num_experts, m.d_ff, d), ("experts", "mlp", "embed"),
                    init="scaled", fan_in=m.d_ff),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_params(d, m.num_shared_experts * m.shared_d_ff)
    return p


def moe_capacity(m: MoESpec, tokens: int) -> int:
    c = int(math.ceil(tokens * m.top_k / m.num_experts * m.capacity_factor))
    return max(8, -(-c // 8) * 8)                          # round up to 8


def _moe_hint(x, *axes):
    """Best-effort sharding constraint: try the full spec, then a
    model-only spec, then identity (CPU tests / manual-axis contexts)."""
    from jax.sharding import PartitionSpec
    try:
        return lax.with_sharding_constraint(x, PartitionSpec(*axes))
    except Exception:
        try:
            only_model = tuple(a if a == "model" else None for a in axes)
            return lax.with_sharding_constraint(
                x, PartitionSpec(*only_model))
        except Exception:
            return x


def moe_fwd(p, m: MoESpec, x):
    """x: (b, s, d) -> (y, aux) with load-balance aux loss.

    Dispatch is PER BATCH ROW (capacity C per sequence): the batch dim is
    the data-sharded axis, so routing never crosses it — each data shard
    dispatches its own rows into an expert buffer whose E dim is sharded
    over "model" (expert parallelism); the only cross-model comm is the
    per-token combine all-reduce, same as any TP layer.  Position-in-expert
    via per-row cumsum over a (s*k, E) one-hot — sort-free.
    """
    b, s, d = x.shape
    E = m.num_experts
    k = m.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(_F32),
                        p["router"].astype(_F32))
    probs = jax.nn.softmax(logits, -1)                      # (b, s, E)
    gates, eidx = lax.top_k(probs, k)                       # (b, s, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    C = moe_capacity(m, s)
    e_flat = eidx.reshape(b, s * k)                         # (b, sk)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)     # (b, sk, E)
    pos = jnp.cumsum(onehot, 1) - onehot
    pos_flat = jnp.take_along_axis(pos, e_flat[..., None], 2)[..., 0]
    keep = pos_flat < C                                     # (b, sk)
    dst = jnp.where(keep, e_flat * C + pos_flat, E * C)     # OOB drop slot
    src = jnp.repeat(jnp.arange(s), k)                      # (sk,) token idx
    bi = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s * k))
    # GATHER-based dispatch: scatter only int32 token ids (tiny), then
    # gather token activations slot-wise.  (A values-scatter materializes
    # a (b, s*k, d) updates tensor that GSPMD replicates across the mesh —
    # observed as multi-TB all-gathers in the dry-run.)
    slot_tok = jnp.zeros((b, E * C + 1), jnp.int32) \
        .at[bi, dst].set(jnp.broadcast_to(src + 1, (b, s * k)),
                         mode="drop")[:, :-1]               # (b, EC); 0=empty
    slot_valid = slot_tok > 0
    buf = jnp.take_along_axis(
        x, jnp.maximum(slot_tok - 1, 0)[..., None], axis=1)  # (b, EC, d)
    buf = jnp.where(slot_valid[..., None], buf, 0).reshape(b, E, C, d)
    buf = _moe_hint(buf, "data", "model", None, None)
    # expert FFN (gated); E sharded over "model" = expert parallelism
    h = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * h, p["w_down"])
    y = _moe_hint(y, "data", "model", None, None)
    # combine: one (b, s, d) gather per routing slot j < k from the flat
    # (b, E*C, d) buffer.  (Measured alternatives, see EXPERIMENTS.md §Perf:
    # a (b,s*k,d) values-scatter and an explicit (e,c)-indexed gather both
    # lower to multi-TB replication collectives under GSPMD; this flat
    # take_along_axis form is the best of the three at every scale tried.)
    y_flat = y.reshape(b, E * C, d)
    out = jnp.zeros((b, s, d), _F32)
    for j in range(k):
        dst_j = dst[:, j::k]                                # (b, s)
        keep_j = keep[:, j::k]
        gath = jnp.take_along_axis(
            y_flat, jnp.minimum(dst_j, E * C - 1)[..., None], axis=1)
        gath = jnp.where(keep_j[..., None], gath.astype(_F32), 0.0)
        out = out + gath * gates[:, :, j][..., None]
    out = out.astype(x.dtype)
    if "shared" in p:
        out = out + mlp_fwd(p["shared"], x)
    # load-balance aux (Switch-style)
    frac_tokens = jnp.mean(jax.nn.one_hot(eidx, E, dtype=_F32),
                           axis=(0, 1, 2))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------


def ssm_params(d: int, s: SSMSpec):
    d_inner = s.expand * d
    h = s.num_heads(d)
    conv_ch = d_inner + 2 * s.d_state
    return {
        "in_proj": P((d, 2 * d_inner + 2 * s.d_state + h),
                     ("embed", "ssm_inner"), init="scaled", fan_in=d),
        "conv_w": P((s.d_conv, conv_ch), ("conv", "ssm_inner"),
                    init="scaled", fan_in=s.d_conv),
        "conv_b": P((conv_ch,), ("ssm_inner",), init="zeros"),
        "a_log": P((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": P((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "d_skip": P((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "norm": rmsnorm_params(d_inner)["scale"],
        "out_proj": P((d_inner, d), ("ssm_inner", "embed"),
                      init="scaled", fan_in=d_inner),
    }


def _segsum(x):
    """x: (..., T) -> (..., T, T) with out[i,j] = sum_{k=j+1..i} x_k (i>=j),
    -inf above the diagonal."""
    T = x.shape[-1]
    xx = jnp.broadcast_to(x[..., None], x.shape + (T,))     # [..., i, j]=x_i
    lower = jnp.tril(jnp.ones((T, T), bool), -1)
    xx = jnp.where(lower, xx, 0.0)
    seg = jnp.cumsum(xx, -2)
    keep = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(keep, seg, -jnp.inf)


def ssd_chunked(xh, dt, A, B, C, chunk: int):
    """SSD (state-space duality) chunked scan.

    xh: (b, s, h, p); dt: (b, s, h) f32 (post-softplus); A: (h,) f32 <0;
    B, C: (b, s, n) f32 (ngroups=1).  Returns (y, final_state) with
    y: (b, s, h, p), final_state: (b, h, p, n) f32.
    """
    b, s, h, pdim = xh.shape
    n = B.shape[-1]
    if s % chunk:
        chunk = s
    nc = s // chunk
    r = lambda t, tail: t.reshape(b, nc, chunk, *tail)
    xc = r(xh.astype(_F32), (h, pdim))
    dtc = r(dt, (h,))
    Bc = r(B.astype(_F32), (n,))
    Cc = r(C.astype(_F32), (n,))
    dA = dtc * A                                           # (b,nc,l,h)
    dA_cum = jnp.cumsum(dA, axis=2)
    xdt = xc * dtc[..., None]                              # dt-weighted input

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))         # (b,nc,h,l,l)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)         # (b,nc,l,l)
    y_diag = jnp.einsum("bclm,bchlm,bcmhp->bclhp", scores, L, xdt)

    # states carried out of each chunk
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (b,nc,h)

    def scan_body(h_prev, inp):
        st, dec = inp                                      # (b,h,p,n),(b,h)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, h, pdim, n), _F32)
    final_state, prev_states = lax.scan(
        scan_body, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (b,nc,h,p,n)

    state_decay_out = jnp.exp(dA_cum)                      # (b,nc,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states,
                       state_decay_out)
    y = (y_diag + y_off).reshape(b, s, h, pdim)
    return y, final_state


def ssm_fwd(p, spec: SSMSpec, x, *, norm_eps=1e-6):
    """Mamba-2 block forward (training).  x: (b, s, d) -> (y, final_states)."""
    b, s, d = x.shape
    d_inner = spec.expand * d
    n = spec.d_state
    h = spec.num_heads(d)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, Braw, Craw, dtraw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], -1)
    # causal depthwise conv over (x, B, C)
    xbc_raw = jnp.concatenate([xin, Braw, Craw], -1)       # (b, s, ch)
    xbc = causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xin, Braw, Craw = jnp.split(xbc, [d_inner, d_inner + n], -1)
    A = -jnp.exp(p["a_log"].astype(_F32))                  # (h,)
    dt = jax.nn.softplus(dtraw.astype(_F32) + p["dt_bias"].astype(_F32))
    xh = xin.reshape(b, s, h, spec.head_dim)
    y, final_state = ssd_chunked(xh, dt, A, Braw, Craw, spec.chunk_size)
    y = y + xh.astype(_F32) * p["d_skip"].astype(_F32)[:, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm"]}, y, norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    # decode-continuation cache: final SSM state + conv tail (last w-1 raw
    # conv inputs), matching ssm_cache layout
    conv_tail = xbc_raw[:, -(spec.d_conv - 1):, :]
    return out, {"state": final_state, "conv": conv_tail}


def causal_conv(x, w, bias):
    """Depthwise causal conv.  x: (b, s, ch); w: (width, ch)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + bias


def ssm_decode(p, spec: SSMSpec, x, cache, *, norm_eps=1e-6):
    """One-token Mamba-2 step.  x: (b, 1, d).
    cache: {"conv": (b, width-1, ch), "state": (b, h, p, n) f32}."""
    b, _, d = x.shape
    d_inner = spec.expand * d
    n = spec.d_state
    h = spec.num_heads(d)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    z, xin, Braw, Craw, dtraw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], -1)
    xbc = jnp.concatenate([xin, Braw, Craw], -1)           # (b, ch)
    conv_hist = cache["conv"]                              # (b, w-1, ch)
    window = jnp.concatenate([conv_hist, xbc[:, None]], 1)  # (b, w, ch)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]
    xin, Braw, Craw = jnp.split(conv_out, [d_inner, d_inner + n], -1)
    A = -jnp.exp(p["a_log"].astype(_F32))
    dt = jax.nn.softplus(dtraw.astype(_F32) + p["dt_bias"].astype(_F32))  # (b,h)
    xh = xin.reshape(b, h, spec.head_dim).astype(_F32)
    Bf = Braw.astype(_F32)                                 # (b, n)
    Cf = Craw.astype(_F32)
    decay = jnp.exp(dt * A)                                # (b, h)
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bf)
    y = jnp.einsum("bn,bhpn->bhp", Cf, state)
    y = y + xh * p["d_skip"].astype(_F32)[:, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z[:, None])
    y = rmsnorm({"scale": p["norm"]}, y, norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), \
        {"conv": new_conv, "state": state}


def ssm_cache(spec: SSMSpec, d: int, batch: int, dtype):
    d_inner = spec.expand * d
    h = spec.num_heads(d)
    ch = d_inner + 2 * spec.d_state
    return {
        "conv": P((batch, spec.d_conv - 1, ch), ("batch", "conv", "ssm_inner"),
                  init="zeros", dtype=dtype),
        "state": P((batch, h, spec.head_dim, spec.d_state),
                   ("batch", "ssm_heads", "head_dim", "ssm_state"),
                   init="zeros", dtype="float32"),
    }
