from repro.models.model import (  # noqa: F401
    abstract_params,
    abstract_params_sds,
    cache_meta,
    decode_layout,
    decode_step,
    forward,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.params import (  # noqa: F401
    P,
    abstract,
    count_params,
    materialize,
    pspecs,
    stack_tree,
)
