"""Parameter metadata: single source of truth for shapes, init and sharding.

Model builders return pytrees whose leaves are :class:`P` — a declarative
(shape, logical-axes, init) record.  From the same tree we derive

* ``materialize(tree, key)``   → concrete arrays (CPU tests / examples),
* ``abstract(tree)``           → ``jax.ShapeDtypeStruct`` stand-ins (dry-run),
* ``pspecs(tree, rules)``      → ``PartitionSpec`` tree (pjit in_shardings),

so shapes, initializers and sharding can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class P:
    """Declarative parameter: shape + logical axis names + init recipe."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]        # logical name per dim (or None)
    init: str = "normal"                   # normal | zeros | ones | scaled
    fan_in: Optional[int] = None           # for init="scaled": 1/sqrt(fan_in)
    dtype: Optional[str] = None            # override model dtype (norms=f32)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def with_prefix(self, n: int, axis_name: str = "layers") -> "P":
        """Stack this param n times along a new leading axis (scan layout)."""
        return dataclasses.replace(
            self, shape=(n,) + self.shape, axes=(axis_name,) + self.axes)


def is_meta(x) -> bool:
    return isinstance(x, P)


def tree_map_meta(fn: Callable, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_meta)


def stack_tree(tree, n: int):
    """Add a leading `layers` axis of size n to every P in the tree."""
    return tree_map_meta(lambda p: p.with_prefix(n), tree)


# ---------------------------------------------------------------------------


def _init_one(p: P, key, default_dtype) -> jax.Array:
    dtype = jnp.dtype(p.dtype or default_dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "scaled":
        fan_in = p.fan_in or (p.shape[-2] if len(p.shape) >= 2 else p.shape[-1])
        std = 1.0 / math.sqrt(max(1, fan_in))
    else:
        std = 0.02
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def materialize(tree, key, default_dtype="float32"):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_meta)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(p, k, default_dtype) for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract(tree, default_dtype="float32"):
    def to_sds(p: P):
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype or default_dtype))
    return tree_map_meta(to_sds, tree)


def pspecs(tree, rules: dict, mesh=None):
    """Map logical axes to mesh axes.

    ``rules`` maps logical-axis-name -> mesh axis (str), tuple of mesh axes,
    or None.  Unlisted logical axes are unsharded.  If two dims of one param
    resolve to the same mesh axis, the later dim is left unsharded (a mesh
    axis may appear at most once in a PartitionSpec).

    With ``mesh`` given, a mapping is dropped (dim left replicated) when the
    dim size is not divisible by the mesh-axis product — e.g. GQA kv_heads=8
    cannot shard over a 16-way model axis, so the KV projections/cache stay
    replicated (the standard GQA serving fallback).
    """
    sizes = dict(mesh.shape) if mesh is not None else {}

    def spec_of(p: P) -> PartitionSpec:
        used = set()
        entries = []
        for dim, name in zip(p.shape, p.axes):
            mesh_axes = rules.get(name) if name else None
            if mesh_axes is None:
                entries.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            free = tuple(a for a in mesh_axes if a not in used)
            if not free:
                entries.append(None)
                continue
            if sizes:
                prod = 1
                for a in free:
                    prod *= sizes[a]
                if dim % prod:
                    entries.append(None)
                    continue
            used.update(free)
            entries.append(free[0] if len(free) == 1 else free)
        return PartitionSpec(*entries)

    return tree_map_meta(spec_of, tree)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_meta)
    total = 0
    for leaf in leaves:
        shape = leaf.shape
        total += int(math.prod(shape)) if shape else 1
    return total
