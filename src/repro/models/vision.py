"""The paper's experimental models (Section VII): CNN (Fashion-MNIST),
VGG-11 (CIFAR-10), ResNet-18 (SVHN) — pure-JAX, pytree-native, with a
``width`` multiplier so the CPU benchmark harness can run reduced variants.

These are the models the paper's tables/figures are produced on; the
transformer zoo handles the assigned at-scale architectures.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.params import P, materialize

_F32 = jnp.float32


def _conv_p(kh, kw, cin, cout):
    return P((kh, kw, cin, cout), (None, None, None, None),
             init="scaled", fan_in=kh * kw * cin)


def _dense_p(cin, cout):
    return P((cin, cout), (None, None), init="scaled", fan_in=cin)


def _conv(x, w, stride=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _maxpool(x, k=2):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, k, k, 1),
                             (1, k, k, 1), "VALID")


def _avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# CNN (paper: 2x conv5x5 + 2 FC, Fashion-MNIST)
# ---------------------------------------------------------------------------


def cnn_params(in_shape=(28, 28, 1), n_classes=10, width=1.0):
    c1, c2, fc = int(32 * width), int(64 * width), int(128 * width)
    h, w, cin = in_shape
    h2, w2 = h // 4, w // 4
    return {
        "conv1": _conv_p(5, 5, cin, c1),
        "conv2": _conv_p(5, 5, c1, c2),
        "fc1": _dense_p(h2 * w2 * c2, fc),
        "fc2": _dense_p(fc, n_classes),
    }


def cnn_fwd(p, x):
    x = jax.nn.relu(_conv(x, p["conv1"]))
    x = _maxpool(x)
    x = jax.nn.relu(_conv(x, p["conv2"]))
    x = _maxpool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["fc1"])
    return x @ p["fc2"]


# ---------------------------------------------------------------------------
# VGG-11 (paper: CIFAR-10)
# ---------------------------------------------------------------------------

_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def vgg11_params(in_shape=(32, 32, 3), n_classes=10, width=1.0):
    params = {}
    cin = in_shape[2]
    i = 0
    for item in _VGG11:
        if item == "M":
            continue
        cout = max(8, int(item * width))
        params[f"conv{i}"] = _conv_p(3, 3, cin, cout)
        cin = cout
        i += 1
    fc = max(16, int(512 * width))
    params["fc1"] = _dense_p(cin, fc)
    params["fc2"] = _dense_p(fc, fc)
    params["fc3"] = _dense_p(fc, n_classes)
    return params


def vgg11_fwd(p, x):
    i = 0
    for item in _VGG11:
        if item == "M":
            x = _maxpool(x)
        else:
            x = jax.nn.relu(_conv(x, p[f"conv{i}"]))
            i += 1
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["fc1"])
    x = jax.nn.relu(x @ p["fc2"])
    return x @ p["fc3"]


# ---------------------------------------------------------------------------
# ResNet-18 (paper: SVHN)
# ---------------------------------------------------------------------------


def resnet18_params(in_shape=(32, 32, 3), n_classes=10, width=1.0):
    w64 = max(8, int(64 * width))
    chans = [w64, w64 * 2, w64 * 4, w64 * 8]
    params = {"stem": _conv_p(3, 3, in_shape[2], w64)}
    cin = w64
    for s, cout in enumerate(chans):
        for b in range(2):
            pref = f"s{s}b{b}"
            params[pref + "_c1"] = _conv_p(3, 3, cin, cout)
            params[pref + "_c2"] = _conv_p(3, 3, cout, cout)
            if cin != cout:
                params[pref + "_proj"] = _conv_p(1, 1, cin, cout)
            cin = cout
    params["fc"] = _dense_p(cin, n_classes)
    return params


def resnet18_fwd(p, x):
    x = jax.nn.relu(_conv(x, p["stem"]))
    cin = p["stem"].shape[-1]
    s = 0
    for s in range(4):
        for b in range(2):
            pref = f"s{s}b{b}"
            stride = 2 if (s > 0 and b == 0) else 1
            h = jax.nn.relu(_conv(x, p[pref + "_c1"], stride=stride))
            h = _conv(h, p[pref + "_c2"])
            sc = x
            if pref + "_proj" in p:
                sc = _conv(x, p[pref + "_proj"], stride=stride)
            x = jax.nn.relu(h + sc)
    x = _avgpool_global(x)
    return x @ p["fc"]


# ---------------------------------------------------------------------------


MODELS = {
    "cnn": (cnn_params, cnn_fwd, "fashion_mnist"),
    "vgg11": (vgg11_params, vgg11_fwd, "cifar10"),
    "resnet18": (resnet18_params, resnet18_fwd, "svhn"),
}


def build_vision(name: str, width: float = 1.0, n_classes: int = 10,
                 key=None):
    mk, fwd, ds = MODELS[name]
    in_shape = (28, 28, 1) if ds == "fashion_mnist" else (32, 32, 3)
    meta = mk(in_shape=in_shape, n_classes=n_classes, width=width)
    if key is None:
        key = jax.random.PRNGKey(0)
    params = materialize(meta, key, "float32")

    def loss_fn(p, batch):
        imgs, labels = batch
        logits = fwd(p, imgs).astype(_F32)
        lse = jax.nn.logsumexp(logits, -1)
        picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32),
                                     -1)[:, 0]
        return jnp.mean(lse - picked)

    def acc_fn(p, batch):
        imgs, labels = batch
        return jnp.mean((jnp.argmax(fwd(p, imgs), -1) == labels)
                        .astype(_F32))

    return params, fwd, loss_fn, acc_fn, ds
