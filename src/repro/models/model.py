"""Stack builder: ArchConfig -> parameters + train / prefill / decode fns.

Layout: the repeating ``layer_pattern`` is first coalesced into GROUPS of
consecutive identical LayerSpecs; parameters are stacked
(pattern_repeats, group_count, ...) and the forward pass is an outer
``lax.scan`` over repeats with an inner ``lax.scan`` over each group — the
lowered HLO is O(#distinct groups), not O(num_layers).  (Gemma-3's
5-local:1-global pattern lowers as 2 group bodies instead of 31 inlined
layers.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import layers as L
from repro.models.params import P, abstract, materialize, stack_tree

_F32 = jnp.float32


def pattern_groups(cfg: ArchConfig) -> List[Tuple[LayerSpec, int]]:
    """Coalesce consecutive identical LayerSpecs into (spec, count) runs."""
    groups: List[Tuple[LayerSpec, int]] = []
    for spec in cfg.layer_pattern:
        if groups and groups[-1][0] == spec:
            groups[-1] = (spec, groups[-1][1] + 1)
        else:
            groups.append((spec, 1))
    return groups


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _block_params(cfg: ArchConfig, spec: LayerSpec) -> Dict[str, Any]:
    d = cfg.d_model
    p: Dict[str, Any] = {"norm_mixer": L.rmsnorm_params(d)}
    if spec.kind == "attn":
        p["mixer"] = L.attention_params(d, spec.attention)
        if cfg.encoder is not None:
            p["cross"] = L.attention_params(
                d, dataclasses.replace(spec.attention, window=None))
            p["norm_cross"] = L.rmsnorm_params(d)
    else:
        p["mixer"] = L.ssm_params(d, spec.ssm)
    if spec.d_ff:
        p["norm_ffn"] = L.rmsnorm_params(d)
        p["ffn"] = L.mlp_params(d, spec.d_ff, spec.gated_mlp)
    elif spec.moe:
        p["norm_ffn"] = L.rmsnorm_params(d)
        p["ffn"] = L.moe_params(d, spec.moe)
    return p


def abstract_params(cfg: ArchConfig):
    d = cfg.d_model
    tree: Dict[str, Any] = {
        "embed": P((cfg.padded_vocab, d), ("vocab", "embed")),
        "blocks": tuple(
            stack_tree(stack_tree(_block_params(cfg, spec), count),
                       cfg.pattern_repeats)
            for spec, count in pattern_groups(cfg)),
        "final_norm": L.rmsnorm_params(d),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = P((d, cfg.padded_vocab), ("embed", "vocab"),
                            init="scaled", fan_in=d)
    if cfg.encoder is not None:
        e = cfg.encoder
        enc_attn = dataclasses.replace(
            cfg.layer_pattern[0].attention, window=None, causal=False)
        enc_block = {
            "norm_mixer": L.rmsnorm_params(d),
            "mixer": L.attention_params(d, enc_attn),
            "norm_ffn": L.rmsnorm_params(d),
            "ffn": L.mlp_params(d, 4 * d, gated=False),
        }
        tree["encoder"] = {
            "blocks": stack_tree(enc_block, e.num_layers),
            "final_norm": L.rmsnorm_params(d),
        }
    return tree


def init_params(cfg: ArchConfig, key):
    return materialize(abstract_params(cfg), key, cfg.dtype)


def abstract_params_sds(cfg: ArchConfig):
    return abstract(abstract_params(cfg), cfg.dtype)


# ---------------------------------------------------------------------------
# Block forward (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _block_fwd(cfg: ArchConfig, spec: LayerSpec, p, x, *, positions,
               enc_out=None, window_override=None, chunk=1024,
               collect_cache=False):
    """Returns (x, aux, cache_entry)."""
    h = L.rmsnorm(p["norm_mixer"], x, cfg.norm_eps)
    cache_entry = {}
    if spec.kind == "attn":
        out, kv = L.attention_fwd(p["mixer"], spec.attention, h,
                                  positions=positions,
                                  window_override=window_override,
                                  chunk=chunk)
        if collect_cache:
            if spec.attention.is_mla:
                cache_entry["ckv"] = kv[0]
            else:
                cache_entry["k"], cache_entry["v"] = kv
        x = x + out
        if enc_out is not None and "cross" in p:
            hc = L.rmsnorm(p["norm_cross"], x, cfg.norm_eps)
            out, _ = L.attention_fwd(p["cross"], spec.attention, hc,
                                     positions=positions, kv=enc_out,
                                     chunk=chunk)
            x = x + out
        if collect_cache and cfg.encoder is not None:
            cache_entry["cross_k"] = jnp.einsum(
                "bsd,dhk->bshk", enc_out, p["cross"]["wk"])
            cache_entry["cross_v"] = jnp.einsum(
                "bsd,dhk->bshk", enc_out, p["cross"]["wv"])
    else:
        out, ssm_cache = L.ssm_fwd(p["mixer"], spec.ssm, h,
                                   norm_eps=cfg.norm_eps)
        if collect_cache:
            cache_entry = ssm_cache
        x = x + out
    aux = jnp.zeros((), _F32)
    if spec.d_ff:
        h = L.rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
        x = x + L.mlp_fwd(p["ffn"], h)
    elif spec.moe:
        h = L.rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
        out, aux = L.moe_fwd(p["ffn"], spec.moe, h)
        x = x + out
    return x, aux, cache_entry


def _encoder_fwd(cfg: ArchConfig, enc_params, frames):
    """frames: (b, src, d) precomputed frame embeddings (stub frontend)."""
    d = cfg.d_model
    src = frames.shape[1]
    pos = jnp.broadcast_to(jnp.arange(src), frames.shape[:2])
    enc_attn = dataclasses.replace(
        cfg.layer_pattern[0].attention, window=None)
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(x, p):
        h = L.rmsnorm(p["norm_mixer"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["mixer"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["mixer"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["mixer"]["wv"])
        q = L.rope(q, pos, enc_attn.rope_theta)
        k = L.rope(k, pos, enc_attn.rope_theta)
        b, s = x.shape[:2]
        g = enc_attn.num_heads // enc_attn.num_kv_heads
        qg = q.reshape(b, s, enc_attn.num_kv_heads, g, enc_attn.head_dim)
        out = L.chunked_attention(qg, k, v, causal=False, chunk=s)
        out = out.reshape(b, s, enc_attn.num_heads * enc_attn.head_dim)
        wo = p["mixer"]["wo"].reshape(-1, d)
        x = x + jnp.einsum("bsk,kd->bsd", out, wo)
        hf = L.rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
        x = x + L.mlp_fwd(p["ffn"], hf)
        return x, None

    x, _ = lax.scan(body, x, enc_params["blocks"])
    return L.rmsnorm(enc_params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------


def _window_override(cfg: ArchConfig, spec: LayerSpec, long_mode: bool):
    if long_mode and spec.kind == "attn" and spec.attention.window is None \
            and cfg.long_strategy == "window_all" and cfg.long_context_window:
        return cfg.long_context_window
    return None


def forward(cfg: ArchConfig, params, tokens, *, frontend_embeds=None,
            remat: str = "full", chunk: int = 1024,
            long_mode: bool = False):
    """tokens: (b, s) int32.  frontend_embeds: (b, s_front, d) for stubbed
    VLM/audio frontends (VLM: prepended to the token embeddings; audio:
    encoder input).  Returns (logits, aux)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encoder_fwd(cfg, params["encoder"], frontend_embeds)
    elif cfg.stub_frontend and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    groups = pattern_groups(cfg)

    def body(carry, group_params):
        x, aux = carry
        for gi, (spec, _) in enumerate(groups):
            wov = _window_override(cfg, spec, long_mode)

            def inner(c2, p_one, spec=spec, wov=wov):
                x2, a2 = c2
                x2, a, _ = _block_fwd(cfg, spec, p_one, x2,
                                      positions=positions, enc_out=enc_out,
                                      window_override=wov, chunk=chunk)
                return (x2, a2 + a), None

            (x, aux), _ = lax.scan(inner, (x, aux), group_params[gi])
        return (x, aux), None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), _F32)), params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x)
    return logits, aux


def _lm_head(cfg, params, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def loss_fn(cfg: ArchConfig, params, tokens, *, frontend_embeds=None,
            remat: str = "full", chunk: int = 1024, aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux = forward(cfg, params, tokens,
                          frontend_embeds=frontend_embeds,
                          remat=remat, chunk=chunk)
    n_front = 0
    if cfg.stub_frontend and frontend_embeds is not None and cfg.encoder is None:
        n_front = frontend_embeds.shape[1]
    lg = logits[:, n_front:, :][:, :-1]
    tgt = tokens[:, 1:]
    lg = lg.astype(_F32)
    lse = jax.nn.logsumexp(lg, -1)
    picked = jnp.take_along_axis(lg, tgt[..., None], -1)[..., 0]
    ce = jnp.mean(lse - picked)
    return ce + aux_weight * aux


def prefill(cfg: ArchConfig, params, tokens, *, frontend_embeds=None,
            chunk: int = 1024):
    """Inference prefill: full forward over the prompt, returning
    (last_token_logits, caches); cache leaves are stacked
    (repeats, group_count, ...) matching cache_meta's full-seq layout."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encoder_fwd(cfg, params["encoder"], frontend_embeds)
    elif cfg.stub_frontend and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    groups = pattern_groups(cfg)

    def body(x, group_params):
        entries = []
        for gi, (spec, _) in enumerate(groups):

            def inner(x2, p_one, spec=spec):
                x2, _, entry = _block_fwd(cfg, spec, p_one, x2,
                                          positions=positions,
                                          enc_out=enc_out, chunk=chunk,
                                          collect_cache=True)
                return x2, entry

            x, group_entries = lax.scan(inner, x, group_params[gi])
            entries.append(group_entries)
        return x, tuple(entries)

    x, caches = lax.scan(body, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x[:, -1:, :])[:, 0]
    return logits, caches


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------


def decode_layout(cfg: ArchConfig, seq_len: int, long_mode: bool):
    """Static per-GROUP cache layout: (kind, ring, window_eff, cache_len)."""
    out = []
    for spec, _ in pattern_groups(cfg):
        if spec.kind == "ssm":
            out.append(("ssm", False, None, 0))
            continue
        window = spec.attention.window
        if long_mode and window is None and cfg.long_strategy == "window_all" \
                and cfg.long_context_window:
            window = cfg.long_context_window
        ring = window is not None and window < seq_len
        cache_len = window if ring else seq_len
        out.append(("attn", ring, window, cache_len))
    return tuple(out)


def _layer_cache_meta(cfg: ArchConfig, spec: LayerSpec, batch: int,
                      cache_len: int):
    d = cfg.d_model
    dt = cfg.dtype
    if spec.kind == "ssm":
        return L.ssm_cache(spec.ssm, d, batch, dt)
    a = spec.attention
    meta = L.attention_cache(a, batch, cache_len, dt)
    if cfg.encoder is not None:
        e = cfg.encoder
        meta["cross_k"] = P((batch, e.src_len, a.num_kv_heads, a.head_dim),
                            ("batch", "enc_seq", "kv_heads", "head_dim"),
                            init="zeros", dtype=dt)
        meta["cross_v"] = P((batch, e.src_len, a.num_kv_heads, a.head_dim),
                            ("batch", "enc_seq", "kv_heads", "head_dim"),
                            init="zeros", dtype=dt)
    return meta


def cache_meta(cfg: ArchConfig, batch: int, seq_len: int,
               long_mode: bool = False):
    """Pytree of P describing the decode cache: tuple per group, leaves
    stacked (pattern_repeats, group_count, ...)."""
    layout = decode_layout(cfg, seq_len, long_mode)
    out = []
    for (spec, count), (_, _, _, cache_len) in zip(pattern_groups(cfg),
                                                   layout):
        m = _layer_cache_meta(cfg, spec, batch, cache_len)
        out.append(stack_tree(stack_tree(m, count), cfg.pattern_repeats))
    return tuple(out)


def decode_step(cfg: ArchConfig, params, caches, pos, token, *,
                seq_len: int, long_mode: bool = False):
    """One decoding step.  caches per cache_meta; pos: scalar int32 (index
    of the current token); token: (b,) int32.  Returns (logits, caches)."""
    layout = decode_layout(cfg, seq_len, long_mode)
    groups = pattern_groups(cfg)
    x = jnp.take(params["embed"], token, axis=0)[:, None]  # (b, 1, d)
    x = x.astype(jnp.dtype(cfg.dtype))

    def body(x, scanned):
        block_p, cache = scanned
        new_cache = []
        for gi, (spec, _) in enumerate(groups):
            _, ring, window_eff, _ = layout[gi]

            def inner(x2, pc, spec=spec, ring=ring, window_eff=window_eff):
                p, c = pc
                h = L.rmsnorm(p["norm_mixer"], x2, cfg.norm_eps)
                if spec.kind == "attn":
                    a = spec.attention
                    self_c = {k: v for k, v in c.items()
                              if k in ("k", "v", "ckv")}
                    out, nc = L.attention_decode(
                        p["mixer"], a, h, self_c, pos=pos,
                        window_override=window_eff, ring=ring)
                    x2 = x2 + out
                    if "cross_k" in c:
                        hc = L.rmsnorm(p["norm_cross"], x2, cfg.norm_eps)
                        g = a.num_heads // a.num_kv_heads
                        q = jnp.einsum("bsd,dhk->bshk", hc,
                                       p["cross"]["wq"])[:, 0]
                        qg = q.reshape(q.shape[0], a.num_kv_heads, g,
                                       a.head_dim)
                        src = c["cross_k"].shape[1]
                        outc = L.decode_attention(
                            qg, c["cross_k"], c["cross_v"], pos=src - 1)
                        outc = outc.reshape(x2.shape[0], 1, -1)
                        wo = p["cross"]["wo"].reshape(-1, cfg.d_model)
                        x2 = x2 + jnp.einsum("bsk,kd->bsd", outc, wo)
                        nc = dict(nc, cross_k=c["cross_k"],
                                  cross_v=c["cross_v"])
                else:
                    out, nc = L.ssm_decode(p["mixer"], spec.ssm, h, c,
                                           norm_eps=cfg.norm_eps)
                    x2 = x2 + out
                if spec.d_ff:
                    hf = L.rmsnorm(p["norm_ffn"], x2, cfg.norm_eps)
                    x2 = x2 + L.mlp_fwd(p["ffn"], hf)
                elif spec.moe:
                    hf = L.rmsnorm(p["norm_ffn"], x2, cfg.norm_eps)
                    out, _ = L.moe_fwd(p["ffn"], spec.moe, hf)
                    x2 = x2 + out
                return x2, nc

            x, group_cache = lax.scan(inner, x, (block_p[gi], cache[gi]))
            new_cache.append(group_cache)
        return x, tuple(new_cache)

    x, new_caches = lax.scan(body, x, (params["blocks"], caches))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x)[:, 0]
    return logits, new_caches
