from repro.optim.adam import (  # noqa: F401
    AdamHyper,
    adam_init,
    adam_step,
    sgd_step,
)
from repro.optim.schedules import constant, cosine, linear_warmup  # noqa: F401
