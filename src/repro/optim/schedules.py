"""Learning-rate schedules (step -> lr multiplier)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def fn(step):
        frac = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        return jnp.asarray(lr, jnp.float32) * frac
    return fn


def cosine(lr: float, total_steps: int, warmup_steps: int = 0,
           final_frac: float = 0.1):
    def fn(step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps or 1))
        prog = jnp.clip((step - warmup_steps) /
                        max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos
    return fn
