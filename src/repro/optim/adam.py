"""Pytree-native Adam / SGD.

The paper's local update rule (Eqs. 3–5) is Adam *without* bias correction
(the moments are aggregated across clients every round, so per-round bias
correction would double-count; this matches Algorithm 1/2 in the paper).
``bias_correction=True`` gives the textbook Adam for centralized training /
comparisons.

The update is elementwise — exactly the op the ``fused_adam`` Pallas kernel
implements; ``adam_step(..., use_kernel=True)`` dispatches per-leaf to it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamHyper:
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-6          # the paper uses 1e-6 (inside the sqrt)
    bias_correction: bool = False
    weight_decay: float = 0.0


class AdamState(NamedTuple):
    m: Any                      # pytree like params
    v: Any
    count: jax.Array            # int32 scalar


def adam_init(params, dtype: Optional[str] = None) -> AdamState:
    def zero_like(x):
        dt = jnp.dtype(dtype) if dtype else x.dtype
        return jnp.zeros(x.shape, dt)

    return AdamState(
        m=jax.tree.map(zero_like, params),
        v=jax.tree.map(zero_like, params),
        count=jnp.zeros((), jnp.int32),
    )


def _adam_leaf(w, g, m, v, h: AdamHyper, count):
    gf = g.astype(_F32)
    mf = h.beta1 * m.astype(_F32) + (1.0 - h.beta1) * gf
    vf = h.beta2 * v.astype(_F32) + (1.0 - h.beta2) * gf * gf
    if h.bias_correction:
        t = count.astype(_F32) + 1.0
        m_hat = mf / (1.0 - h.beta1 ** t)
        v_hat = vf / (1.0 - h.beta2 ** t)
    else:
        m_hat, v_hat = mf, vf
    upd = m_hat / jnp.sqrt(v_hat + h.eps)       # paper: eps inside the sqrt
    if h.weight_decay:
        upd = upd + h.weight_decay * w.astype(_F32)
    w_new = w.astype(_F32) - h.lr * upd
    return (w_new.astype(w.dtype), mf.astype(m.dtype), vf.astype(v.dtype))


def adam_step(params, grads, state: AdamState, h: AdamHyper,
              use_kernel: bool = False):
    """One Adam step.  Returns (new_params, new_state)."""
    if use_kernel:
        from repro.kernels.fused_adam import ops as fused

        def leaf(w, g, m, v):
            return fused.fused_adam(w, g, m, v, h, state.count)
    else:
        def leaf(w, g, m, v):
            return _adam_leaf(w, g, m, v, h, state.count)

    # flatten/unflatten explicitly: the params tree may itself contain
    # tuples (e.g. the stacked `blocks` tuple), so tuple-as-leaf tricks
    # would corrupt the structure.
    pw, treedef = jax.tree_util.tree_flatten(params)
    pg = treedef.flatten_up_to(grads)
    pm = treedef.flatten_up_to(state.m)
    pv = treedef.flatten_up_to(state.v)
    outs = [leaf(w, g, m, v) for w, g, m, v in zip(pw, pg, pm, pv)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, AdamState(new_m, new_v, state.count + 1)


def sgd_step(params, grads, lr: float, momentum_state=None, momentum=0.0):
    """Vanilla / momentum SGD (FedSGD baseline)."""
    if momentum and momentum_state is not None:
        new_mom = jax.tree.map(
            lambda b, g: momentum * b.astype(_F32) + g.astype(_F32),
            momentum_state, grads)
        new_p = jax.tree.map(
            lambda w, b: (w.astype(_F32) - lr * b).astype(w.dtype),
            params, new_mom)
        return new_p, new_mom
    new_p = jax.tree.map(
        lambda w, g: (w.astype(_F32) - lr * g.astype(_F32)).astype(w.dtype),
        params, grads)
    return new_p, momentum_state
