"""npz-based pytree checkpointing with path-keyed leaves + JSON metadata.

Layout-agnostic: leaves are saved under their joined tree path, so any
pytree of arrays (params, FedState, decode caches) round-trips.  Sharded
arrays are gathered to host before save (fine at example scale; a real
multi-host deployment would use a tensorstore-backed writer — noted in
docs/ARCHITECTURE.md §7 as the one substrate we stub at cluster scale).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.core.fed import FedState


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: str | Path, meta: dict | None = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(kp)] = np.asarray(leaf)
    np.savez(path, **flat)
    if meta is not None:
        Path(str(path) + ".meta.json").write_text(json.dumps(meta, indent=1))


def load_pytree(like: Any, path: str | Path) -> Any:
    """Load into the structure of ``like`` (shapes/dtypes validated)."""
    data = np.load(str(path) if str(path).endswith(".npz")
                   else str(path) + ".npz")
    kps, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in kps:
        key = _path_str(kp)
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_fed_state(state: FedState, path: str | Path, meta: dict | None = None):
    save_pytree(state._asdict(), path, meta)


def load_fed_state(like: FedState, path: str | Path) -> FedState:
    d = load_pytree(like._asdict(), path)
    return FedState(**d)
