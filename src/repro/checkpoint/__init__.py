from repro.checkpoint.io import load_pytree, save_pytree, save_fed_state, load_fed_state  # noqa: F401
