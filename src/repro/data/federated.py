"""Federated partitioning + batch iteration.

Non-IID: Dirichlet label-skew split (concentration theta), the protocol of
Yurochkin et al. / Wang et al. used by the paper (theta = 0.1 in Sec. VII).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, theta: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Split example indices across clients with Dirichlet(theta) label
    proportions.  Lower theta => more skew.  Every client gets >= 1 item."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet([theta] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cl, part in enumerate(np.split(idx, cuts)):
            client_idx[cl].extend(part.tolist())
    # guarantee non-empty clients
    all_idx = np.arange(len(labels))
    for cl in range(n_clients):
        if not client_idx[cl]:
            client_idx[cl].append(int(rng.choice(all_idx)))
        rng.shuffle(client_idx[cl])
    return [np.asarray(ix, dtype=np.int64) for ix in client_idx]


def iid_partition(n: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.asarray(p, dtype=np.int64)
            for p in np.array_split(perm, n_clients)]


def client_batches(arrays: Sequence[np.ndarray], parts: List[np.ndarray],
                   batch_size: int, seed: int = 0):
    """One client-major batch per call: returns a pytree-compatible tuple of
    stacked arrays with leading dim (n_clients, batch_size, ...).  Clients
    with fewer than batch_size examples sample with replacement (the paper's
    D~_n minibatch)."""
    rng = np.random.default_rng(seed)
    picks = []
    for part in parts:
        replace = len(part) < batch_size
        picks.append(rng.choice(part, size=batch_size, replace=replace))
    picks = np.stack(picks)                       # (C, B)
    return tuple(np.stack([a[p] for p in picks]) for a in arrays), \
        np.asarray([len(p) for p in parts], np.float32)
