"""Synthetic datasets (the container is offline — no torchvision downloads).

* ``synthetic_image_dataset`` — class-prototype + noise image classification
  sets standing in for Fashion-MNIST (28x28x1), CIFAR-10 / SVHN (32x32x3).
  Labels are real (prototype index) so federated non-IID label skew via the
  Dirichlet partitioner is meaningful and accuracy is a real signal.
* ``synthetic_tokens`` — Zipf-distributed token streams with a per-client
  topic bias (non-IID for language models).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


_SHAPES = {
    "fashion_mnist": (28, 28, 1),
    "cifar10": (32, 32, 3),
    "svhn": (32, 32, 3),
}


def synthetic_image_dataset(name: str, n: int, n_classes: int = 10,
                            seed: int = 0, noise: float = 0.35
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (n, H, W, C) float32 in [0,1]-ish, labels (n,))."""
    h, w, c = _SHAPES[name]
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.5, 0.25, size=(n_classes, h, w, c))
    # low-frequency structure so convs have something to learn
    for k in range(n_classes):
        yy, xx = np.mgrid[0:h, 0:w]
        wave = np.sin(2 * np.pi * (k + 1) * xx / w) * \
            np.cos(2 * np.pi * (k % 3 + 1) * yy / h)
        protos[k, :, :, 0] += 0.3 * wave
    labels = rng.integers(0, n_classes, size=n)
    imgs = protos[labels] + noise * rng.normal(size=(n, h, w, c))
    return imgs.astype(np.float32), labels.astype(np.int32)


def synthetic_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
                     topic: int = 0, n_topics: int = 8) -> np.ndarray:
    """Zipf tokens with a topic-dependent permutation of the vocabulary —
    different topics => shifted unigram distributions (non-IID clients)."""
    rng = np.random.default_rng(seed + 7919 * topic)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    perm = np.random.default_rng(topic).permutation(vocab)
    toks = rng.choice(vocab, size=(n_seqs, seq_len), p=p)
    return perm[toks].astype(np.int32)


def synthetic_frontend_embeds(n: int, tokens: int, d_model: int,
                              seed: int = 0) -> np.ndarray:
    """Precomputed patch/frame embeddings for stubbed VLM/audio frontends."""
    rng = np.random.default_rng(seed)
    return (rng.normal(0, 0.02, size=(n, tokens, d_model))
            .astype(np.float32))
