"""Deterministic client-churn model for the buffered-async driver.

Millions of intermittently-connected devices means the traffic pattern
is churn: clients arrive, straggle, and vanish mid-round.  Async
aggregation bugs live in rare interleavings of exactly those events, so
this model is built for *replay*: every quantity is a pure function of
``(seed, client, attempt)`` — no wall clock, no global RNG state, no
dependence on the order the simulator happens to ask.  Two simulations
with the same ``ChurnConfig`` therefore see the **same** event schedule
bitwise, and any failing schedule is reproducible from its seed alone
(see docs/async.md for the replay recipe).

Time is a *virtual clock*: integer ticks advanced only by the event
queue in :mod:`repro.core.async_fed`.  A tick has no physical meaning
beyond ordering; ``base_duration`` just sets the scale on which
staleness accrues.

The three churn behaviours, per dispatch:

* **jitter**     — uniform extra ticks on the compute duration, so
  deliveries interleave instead of arriving in lockstep;
* **straggler**  — with ``straggler_prob``, the duration is multiplied
  by ``straggler_factor``: the update arrives many server steps late
  and may exceed the driver's staleness cutoff;
* **drop**       — with ``drop_prob``, the client trains and compresses
  but the update is lost before delivery (device offline, network
  partition).  The driver must leave that client's error-feedback
  residual and local moments untouched — per-client compressor state
  survives dropout, it is never rezeroed (the Efficient-Adam lesson).

Tests can pin exact fates via ``script`` without touching the seeded
path for every other (client, attempt).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Seeded churn parameters.  The all-defaults config is ZERO churn:
    every dispatch takes exactly ``base_duration`` ticks and always
    delivers — the degenerate schedule under which the async driver is
    bit-identical to the synchronous round (tests/test_async_fed.py)."""
    seed: int = 0
    base_duration: int = 8        # ticks from dispatch to delivery
    jitter: int = 0               # + uniform{0..jitter} extra ticks
    straggler_prob: float = 0.0   # P[duration *= straggler_factor]
    straggler_factor: int = 6
    drop_prob: float = 0.0        # P[update lost after compress]
    rejoin_delay: int = 0         # ticks before a client re-dispatches

    def __post_init__(self):
        assert self.base_duration >= 1 and self.jitter >= 0
        assert 0.0 <= self.straggler_prob <= 1.0
        assert 0.0 <= self.drop_prob <= 1.0
        assert self.straggler_factor >= 1 and self.rejoin_delay >= 0


class ClientFate(NamedTuple):
    """What happens to one (client, attempt) dispatch."""
    duration: int                 # virtual ticks until delivery/loss
    drop: bool                    # lost after compress, before delivery


class ChurnModel:
    """Pure ``(client, attempt) -> ClientFate`` lookup.

    Each fate draws from ``np.random.default_rng([seed, client,
    attempt])`` — an order-independent counter-mode construction, so the
    schedule does not depend on simulation interleaving and replays
    bitwise from the seed.  ``script`` overrides individual fates
    (fault-injection tests): ``{(client, attempt): ClientFate(...)}``.
    """

    def __init__(self, cfg: ChurnConfig, n_clients: int,
                 script: Optional[Dict[Tuple[int, int],
                                       ClientFate]] = None):
        assert n_clients >= 1
        self.cfg = cfg
        self.n_clients = n_clients
        self.script = dict(script or {})

    def fate(self, client: int, attempt: int) -> ClientFate:
        key = (int(client), int(attempt))
        if key in self.script:
            return self.script[key]
        cfg = self.cfg
        rng = np.random.default_rng([cfg.seed, key[0], key[1]])
        # fixed draw order (jitter, straggler, drop) so adding a knob
        # later appends draws instead of reshuffling existing schedules
        dur = cfg.base_duration
        if cfg.jitter:
            dur += int(rng.integers(0, cfg.jitter + 1))
        if cfg.straggler_prob and rng.random() < cfg.straggler_prob:
            dur *= cfg.straggler_factor
        drop = bool(cfg.drop_prob) and rng.random() < cfg.drop_prob
        return ClientFate(int(dur), bool(drop))

    def participation_pool(self, n_active: int) -> np.ndarray:
        """The ``n_active`` clients admitted to the async dispatch pool
        (partial participation; ``n_active`` comes from
        ``fed.active_client_count`` — the shared sync/async seam).  A
        seeded permutation, independent of per-dispatch fates."""
        assert 1 <= n_active <= self.n_clients
        rng = np.random.default_rng([self.cfg.seed, 0x9001])
        return np.sort(rng.permutation(self.n_clients)[:n_active])
