from repro.data.federated import (  # noqa: F401
    dirichlet_partition,
    iid_partition,
    client_batches,
)
from repro.data.churn import (  # noqa: F401
    ChurnConfig,
    ChurnModel,
    ClientFate,
)
from repro.data.synthetic import (  # noqa: F401
    synthetic_image_dataset,
    synthetic_tokens,
    synthetic_frontend_embeds,
)
