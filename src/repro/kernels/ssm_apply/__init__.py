from repro.kernels.ssm_apply import ops, ref  # noqa: F401
