"""Wrapper: arbitrary shapes -> tiles -> fused mask-apply; combined with
topk_mask.ops this is the full kernel-path sparsification:

    mask, tau, _ = topk_mask_kernel(dW, k)
    sW, sM, sV   = ssm_apply(tau, dW, dM, dV)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_apply.ref import ssm_apply_ref
from repro.kernels.ssm_apply.ssm_apply import LANES, SUBLANES, ssm_apply_2d

_TILE = SUBLANES * LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssm_apply(tau, dw, dm, dv):
    n = dw.size
    if n < _TILE:
        return ssm_apply_ref(tau, dw, dm, dv)
    pad = (-n) % _TILE
    prep = lambda x: jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, LANES)
    wo, mo, vo = ssm_apply_2d(tau, prep(dw), prep(dm), prep(dv),
                              interpret=_interpret())
    unprep = lambda x2, like: x2.reshape(-1)[:n].reshape(like.shape)
    return unprep(wo, dw), unprep(mo, dm), unprep(vo, dv)
