"""Wrapper: arbitrary shapes -> tiles -> fused mask-apply; combined with
topk_mask.ops this is the full kernel-path sparsification:

    tau, _      = select_tau_kernel(dW, k)
    sW, sM, sV, err = ssm_apply_ef(tau, dW, dM, dV)

``ssm_apply`` is the original 3-in/3-out apply (kept for the mask-only
consumers); ``ssm_apply_ef`` is the fused compress hot path used by the
kernel-backend dispatch in core/sparsify.py — one streaming pass that
also performs the error-feedback residual update and the optional
``value_dtype`` wire cast (contract in docs/kernels.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_apply.ref import ssm_apply_ef_ref, ssm_apply_ref
from repro.kernels.ssm_apply.ssm_apply import (
    LANES, SUBLANES, ssm_apply_2d, ssm_apply_ef_2d)

_TILE = SUBLANES * LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssm_apply(tau, dw, dm, dv):
    n = dw.size
    if n < _TILE:
        return ssm_apply_ref(tau, dw, dm, dv)
    pad = (-n) % _TILE
    prep = lambda x: jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, LANES)
    wo, mo, vo = ssm_apply_2d(tau, prep(dw), prep(dm), prep(dv),
                              interpret=_interpret())
    unprep = lambda x2, like: x2.reshape(-1)[:n].reshape(like.shape)
    return unprep(wo, dw), unprep(mo, dm), unprep(vo, dv)


def ssm_apply_ef(tau, dw, dm, dv, score=None, *, with_residual=True,
                 value_dtype=None):
    """Fused compress pass over arbitrary-shaped (same-shape) tensors.

    Returns ``(sw, sm, sv)`` or ``(sw, sm, sv, err)``.  ``score`` (the
    tensor whose |.| the shared mask thresholds) defaults to ``dw``;
    tensors below one (8, 1024) tile fall back to the composed-jnp
    oracle, which is bit-identical by construction."""
    n = dw.size
    if n < _TILE:
        return ssm_apply_ef_ref(tau, dw, dm, dv, score,
                                with_residual=with_residual,
                                value_dtype=value_dtype)
    pad = (-n) % _TILE
    prep = lambda x: jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, LANES)
    outs = ssm_apply_ef_2d(
        tau, prep(dw), prep(dm), prep(dv),
        None if score is None else prep(score),
        with_residual=with_residual, value_dtype=value_dtype,
        interpret=_interpret())
    unprep = lambda x2: x2.reshape(-1)[:n].reshape(dw.shape)
    return tuple(unprep(o) for o in outs)
