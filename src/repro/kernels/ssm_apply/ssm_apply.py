"""Fused shared-sparse-mask application (Algorithm 2 line 10).

Given the shared threshold tau (from topk_mask over |dW|), produce the three
sparse deltas in ONE streaming pass: a single |dW| >= tau compare drives all
three selects — 3 loads + 3 stores per tile instead of three separate
masked-select ops each re-reading dW for the mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)


def _kernel(tau_ref, w_ref, m_ref, v_ref, wo_ref, mo_ref, vo_ref):
    keep = jnp.abs(w_ref[...].astype(jnp.float32)) >= tau_ref[0]
    zero = jnp.zeros((), wo_ref.dtype)
    wo_ref[...] = jnp.where(keep, w_ref[...], zero)
    mo_ref[...] = jnp.where(keep, m_ref[...], zero.astype(mo_ref.dtype))
    vo_ref[...] = jnp.where(keep, v_ref[...], zero.astype(vo_ref.dtype))


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_apply_2d(tau, dw, dm, dv, *, interpret: bool = True):
    grid = (dw.shape[0] // SUBLANES,)
    spec = pl.BlockSpec(BLOCK, lambda i, s: (i, 0))
    out_shape = tuple(jax.ShapeDtypeStruct(t.shape, t.dtype)
                      for t in (dw, dm, dv))
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec, spec],
            out_specs=(spec, spec, spec),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray([tau], jnp.float32), dw, dm, dv)
