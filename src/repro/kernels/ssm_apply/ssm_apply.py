"""Fused shared-sparse-mask application (Algorithm 2 line 10).

Given the shared threshold tau (from topk_mask over the score tensor),
produce the three sparse deltas in ONE streaming pass: a single
|score| >= tau compare drives all three selects — 3 loads + 3 stores per
tile instead of three separate masked-select ops each re-reading the
score for the mask.

``ssm_apply_ef_2d`` is the full fused compress hot path: the same single
pass additionally (a) casts kept values through an optional transport
dtype (``value_dtype``, e.g. bf16 wire values carried in f32) and
(b) emits the error-feedback residual ``dw - sw`` (exactly the composed
``tree_sub(dW, sW)`` arithmetic: f32 subtract, cast back).  Without the
fusion the compress path is 3-4 elementwise rounds over HBM (mask apply
x3, cast, residual subtract); fused, every delta streams through VMEM
once.  Contract and backend-dispatch rules: docs/kernels.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)


def _kernel(tau_ref, w_ref, m_ref, v_ref, wo_ref, mo_ref, vo_ref):
    keep = jnp.abs(w_ref[...].astype(jnp.float32)) >= tau_ref[0]
    zero = jnp.zeros((), wo_ref.dtype)
    wo_ref[...] = jnp.where(keep, w_ref[...], zero)
    mo_ref[...] = jnp.where(keep, m_ref[...], zero.astype(mo_ref.dtype))
    vo_ref[...] = jnp.where(keep, v_ref[...], zero.astype(vo_ref.dtype))


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_apply_2d(tau, dw, dm, dv, *, interpret: bool = True):
    grid = (dw.shape[0] // SUBLANES,)
    spec = pl.BlockSpec(BLOCK, lambda i, s: (i, 0))
    out_shape = tuple(jax.ShapeDtypeStruct(t.shape, t.dtype)
                      for t in (dw, dm, dv))
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec, spec],
            out_specs=(spec, spec, spec),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray([tau], jnp.float32), dw, dm, dv)


def _make_ef_kernel(has_score: bool, with_residual: bool, value_dtype):
    """Kernel body for the fused compress pass.  Static shape:
    inputs  [score?], dw, dm, dv
    outputs sw, sm, sv, [err?]
    keep = |score or dw| >= tau; kept values round-trip through
    ``value_dtype``; err = (dw - sw) in f32, cast back to dw's dtype."""
    vdt = None if value_dtype is None else jnp.dtype(value_dtype)

    def cast(x):
        return x if vdt is None else x.astype(vdt).astype(x.dtype)

    def body(tau_ref, *refs):
        if has_score:
            score, refs = refs[0], refs[1:]
        w_ref, m_ref, v_ref = refs[:3]
        outs = refs[3:]
        if not has_score:
            score = w_ref
        keep = jnp.abs(score[...].astype(jnp.float32)) >= tau_ref[0]
        w = w_ref[...]
        zero = jnp.zeros((), w.dtype)
        sw = jnp.where(keep, cast(w), zero)
        outs[0][...] = sw
        outs[1][...] = jnp.where(keep, cast(m_ref[...]),
                                 zero.astype(m_ref.dtype))
        outs[2][...] = jnp.where(keep, cast(v_ref[...]),
                                 zero.astype(v_ref.dtype))
        if with_residual:
            outs[3][...] = (w.astype(jnp.float32) - sw.astype(jnp.float32)
                            ).astype(w.dtype)

    return body


@functools.partial(jax.jit, static_argnames=("with_residual", "value_dtype",
                                             "interpret"))
def ssm_apply_ef_2d(tau, dw, dm, dv, score=None, *,
                    with_residual: bool = True, value_dtype=None,
                    interpret: bool = True):
    """Fused compress pass over (R, LANES) tiles.

    Returns ``(sw, sm, sv)`` or ``(sw, sm, sv, err)`` when
    ``with_residual``.  ``score`` defaults to ``dw`` (the paper's ssm_w
    rule) without streaming it twice; pass a distinct score tensor for
    the ssm_m / ssm_v / fairness_top mask rules."""
    has_score = score is not None
    grid = (dw.shape[0] // SUBLANES,)
    spec = pl.BlockSpec(BLOCK, lambda i, s: (i, 0))
    ins = ([score] if has_score else []) + [dw, dm, dv]
    outs = [dw, dm, dv] + ([dw] if with_residual else [])
    out_shape = tuple(jax.ShapeDtypeStruct(t.shape, t.dtype) for t in outs)
    res = pl.pallas_call(
        _make_ef_kernel(has_score, with_residual, value_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec] * len(ins),
            out_specs=tuple([spec] * len(outs)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray([tau], jnp.float32), *ins)
    return res
