"""Pure-jnp oracle for ssm_apply."""
from __future__ import annotations

import jax.numpy as jnp


def ssm_apply_ref(tau, dw, dm, dv):
    keep = jnp.abs(dw.astype(jnp.float32)) >= tau
    z = jnp.zeros((), dw.dtype)
    return (jnp.where(keep, dw, z),
            jnp.where(keep, dm, z.astype(dm.dtype)),
            jnp.where(keep, dv, z.astype(dv.dtype)))
