"""Pure-jnp oracles for ssm_apply / ssm_apply_ef.

``ssm_apply_ef_ref`` is the COMPOSED form of the fused kernel — the same
arithmetic the reference compress path performs as separate elementwise
rounds (mask apply x3, value_dtype round-trip, f32 residual subtract).
The kernel must match it bit-exactly; tests/test_sparsify_dispatch.py
asserts so.  It is also the small-tensor fallback of the ops.py wrapper.
"""
from __future__ import annotations

import jax.numpy as jnp


def ssm_apply_ref(tau, dw, dm, dv):
    keep = jnp.abs(dw.astype(jnp.float32)) >= tau
    z = jnp.zeros((), dw.dtype)
    return (jnp.where(keep, dw, z),
            jnp.where(keep, dm, z.astype(dm.dtype)),
            jnp.where(keep, dv, z.astype(dv.dtype)))


def ssm_apply_ef_ref(tau, dw, dm, dv, score=None, *,
                     with_residual=True, value_dtype=None):
    """Composed-jnp oracle of ssm_apply_ef_2d (same output tuple)."""
    s = dw if score is None else score
    keep = jnp.abs(s.astype(jnp.float32)) >= tau
    vdt = None if value_dtype is None else jnp.dtype(value_dtype)
    cast = (lambda x: x) if vdt is None else \
        (lambda x: x.astype(vdt).astype(x.dtype))
    z = jnp.zeros((), dw.dtype)
    sw = jnp.where(keep, cast(dw), z)
    sm = jnp.where(keep, cast(dm), z.astype(dm.dtype))
    sv = jnp.where(keep, cast(dv), z.astype(dv.dtype))
    if not with_residual:
        return sw, sm, sv
    err = (dw.astype(jnp.float32) - sw.astype(jnp.float32)).astype(dw.dtype)
    return sw, sm, sv, err
