"""Pallas TPU kernels for the algorithm's compute hot-spots.

Each kernel package ships:
  <name>.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target);
  ops.py    — jit'd dispatch wrapper (auto interpret=True off-TPU);
  ref.py    — pure-jnp oracle used by the allclose test sweeps.

Kernels:
  topk_mask   — O(d) threshold selection for the paper's Top_k sparsifier
                (vs O(d log d) sort): blockwise |x| count over log2-spaced
                bins + one linear refinement pass, each pass streaming
                8x1024 VMEM tiles.
  fused_adam  — the paper's local update (Eqs. 3-5) for w/m/v in a single
                VMEM round-trip (4 reads + 3 writes vs 9+ unfused).
  ssm_apply   — fused shared-mask application: one |dW|>=tau compare drives
                the masking of all three delta streams (6 reads, 3 writes).
"""
