"""jit'd wrapper: 3-pass streaming threshold top-k mask.

Returns (mask, tau, achieved_count).  Count semantics: >= k, over-selecting
by at most one refinement bin (<=3% of k worst case); ties at tau share the
mask.  Precision note: per-tile counts are f32 (exact to 2^24 per tile —
tiles are 8192 elements, so exact), and the cross-tile accumulation is an
f32 add chain whose error is << 1 count for d <= 2^40.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_mask.topk_mask import (
    LANES, SUBLANES, N_BINS, absmax_2d, apply_mask_2d, count_ge_2d)
from repro.kernels.topk_mask.ref import linear_taus, log2_taus

_TILE = SUBLANES * LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def topk_mask_kernel(x, k: int):
    """x: any shape; k: static int.  Returns (mask bool, tau, count)."""
    n = x.size
    pad = (-n) % _TILE
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, LANES)
    interp = _interpret()

    absmax = absmax_2d(flat, interpret=interp)
    taus1 = log2_taus(absmax)
    counts1 = count_ge_2d(taus1, flat, interpret=interp)
    # padding contributes |0| >= tau counts only at tau == 0; taus > 0 here
    idx = jnp.argmax(counts1 >= k)
    hi = jnp.where(idx > 0, taus1[idx - 1], absmax)
    lo = taus1[idx]
    taus2 = linear_taus(lo, hi)
    counts2 = count_ge_2d(taus2, flat, interpret=interp)
    idx2 = jnp.argmax(counts2 >= k)
    tau = taus2[idx2]
    tau = jnp.where(k >= n, jnp.zeros((), jnp.float32), tau)
    count = counts2[idx2]

    mask = apply_mask_2d(tau, flat, interpret=interp)
    mask = mask.reshape(-1)[:n].reshape(x.shape).astype(bool)
    return mask, tau, count
