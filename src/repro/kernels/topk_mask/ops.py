"""jit'd wrapper: 3-pass streaming threshold top-k mask.

``select_tau_kernel`` runs the selection passes only (absmax -> log2
histogram -> linear refine) and returns ``(tau, achieved_count)``; the
fused compress path (kernels/ssm_apply/ops.py:ssm_apply_ef) consumes tau
directly and never materializes the mask.  ``topk_mask_kernel`` adds the
elementwise apply pass and returns ``(mask, tau, achieved_count)``.

Count semantics: >= k, over-selecting by at most one refinement bin —
the bin width is ~1.4% of tau (half-octave bracket / 31 linear bins), so
the count overshoot scales with the |x|-density at tau: <0.5% of k for
typical delta distributions, enforced at ``overselect_bound(k)``
(6% of k + 8) as the contract.  Ties at tau share the mask.

Precision note: per-tile counts are f32 (exact to 2^24
per tile — tiles are 8192 elements, so exact), and the cross-tile
accumulation is an f32 add chain whose error is << 1 count for d <= 2^40.
Algorithm walkthrough and the guarantee's derivation: docs/kernels.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_mask.topk_mask import (
    LANES, SUBLANES, N_BINS, absmax_2d, apply_mask_2d, count_ge_2d)
from repro.kernels.topk_mask.ref import linear_taus, log2_taus

_TILE = SUBLANES * LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def overselect_bound(k: int, n: int | None = None) -> int:
    """Contracted worst-case ``achieved_count - k`` of the 3-pass
    selection: one linear refinement bin of a half-octave bracket (bin
    width ~1.4% of tau; the count overshoot it admits depends on the
    |x|-density at tau — ~4% of k for a Gaussian at alpha=0.05), bounded
    at 6% of k plus a small absolute slack for ties/degenerate brackets
    at tiny k.  Tests and the benchmark harness assert against THIS
    function so the code and docs/kernels.md can never drift apart."""
    bound = int(0.06 * k) + 8
    return min(bound, (n - k) if n is not None else bound)


def select_tau_kernel(x, k: int):
    """x: any shape; k: static int.  Selection passes only.
    Returns (tau f32 scalar, achieved_count f32 scalar)."""
    n = x.size
    pad = (-n) % _TILE
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, LANES)
    interp = _interpret()

    absmax = absmax_2d(flat, interpret=interp)
    taus1 = log2_taus(absmax)
    counts1 = count_ge_2d(taus1, flat, interpret=interp)
    # padding contributes |0| >= tau counts only at tau == 0; taus > 0 here
    idx = jnp.argmax(counts1 >= k)
    hi = jnp.where(idx > 0, taus1[idx - 1], absmax)
    lo = taus1[idx]
    taus2 = linear_taus(lo, hi)
    counts2 = count_ge_2d(taus2, flat, interpret=interp)
    idx2 = jnp.argmax(counts2 >= k)
    tau = taus2[idx2]
    tau = jnp.where(k >= n, jnp.zeros((), jnp.float32), tau)
    count = jnp.where(k >= n, jnp.asarray(n, jnp.float32), counts2[idx2])
    return tau, count


def topk_mask_kernel(x, k: int):
    """x: any shape; k: static int.  Returns (mask bool, tau, count)."""
    n = x.size
    tau, count = select_tau_kernel(x, k)
    pad = (-n) % _TILE
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, LANES)
    mask = apply_mask_2d(tau, flat, interpret=_interpret())
    mask = mask.reshape(-1)[:n].reshape(x.shape).astype(bool)
    return mask, tau, count
