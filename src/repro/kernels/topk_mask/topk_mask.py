"""Top-k threshold selection kernel — the paper's Top_k sparsifier hot-spot.

A sort-based top-k over d ~ 1e9..1e12 is O(d log d) compute and worse, it
is HBM-layout hostile (global sort = multi-pass shuffles).  The mask only
needs a *threshold* tau with count(|x| >= tau) ~ k.  TPU-native selection:

  pass 1 (absmax):   stream (8, 1024) VMEM tiles, per-grid-step running
                     max into a (1, 1) SMEM-resident accumulator output.
  pass 2 (histogram): per tile, count |x| >= tau_j for 32 log2-spaced
                     candidates tau_j = absmax * 2^(-j/2); accumulate
                     counts into a (1, 32) output (f32 adds — counts to
                     2^24 exact per block, summed in f64-free streaming;
                     documented precision note in ops.py).
  pass 3 (refine):   32 linear candidates between the two bracketing
                     log2 levels; same kernel.
  apply:             mask = |x| >= tau (elementwise, fused downstream by
                     ssm_apply).

Each pass is one streaming read of x: O(d) total, no sort, no layout
change.  Count exactness: the final tau over-selects by at most the
refinement-bin width (<0.5% of k typical; contract bound
``ops.overselect_bound`` = 6% of k + 8); ties share the bin edge.  The
ops.py wrapper reports the achieved count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 1024
SUBLANES = 8
BLOCK = (SUBLANES, LANES)
N_BINS = 32


def _absmax_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))
    o_ref[0, 0] = jnp.maximum(o_ref[0, 0], m)


@functools.partial(jax.jit, static_argnames=("interpret",))
def absmax_2d(x, *, interpret: bool = True):
    """x: (R, LANES) -> f32 scalar max|x|."""
    grid = (x.shape[0] // SUBLANES,)
    out = pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(BLOCK, lambda i: (i, 0))],
        # deliberately sub-tile: a (1, 1) running-max accumulator the
        # grid revisits every step — scalar, SMEM-resident, not a
        # streamed VMEM vector tile
        out_specs=pl.BlockSpec(  # repro-lint: disable=pallas-contract
            (1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[0, 0]


def _count_kernel(taus_ref, x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = jnp.abs(x_ref[...].astype(jnp.float32))
    # unrolled over the N_BINS candidates: VPU reductions in registers
    for j in range(N_BINS):
        cnt = jnp.sum((a >= taus_ref[j]).astype(jnp.float32))
        o_ref[0, j] += cnt


@functools.partial(jax.jit, static_argnames=("interpret",))
def count_ge_2d(taus, x, *, interpret: bool = True):
    """taus: f32[N_BINS] candidates; x: (R, LANES).
    Returns f32[N_BINS] counts of |x| >= tau_j."""
    grid = (x.shape[0] // SUBLANES,)
    out = pl.pallas_call(
        _count_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(BLOCK, lambda i, s: (i, 0))],
            # deliberately sub-tile: the (1, N_BINS) histogram
            # accumulator is revisited every grid step, not streamed
            out_specs=pl.BlockSpec(  # repro-lint: disable=pallas-contract
                (1, N_BINS), lambda i, s: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, N_BINS), jnp.float32),
        interpret=interpret,
    )(taus, x)
    return out[0]


def _apply_kernel(tau_ref, x_ref, o_ref):
    a = jnp.abs(x_ref[...].astype(jnp.float32))
    o_ref[...] = (a >= tau_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_mask_2d(tau, x, *, interpret: bool = True):
    """mask = |x| >= tau as int8 (bool VMEM stores are int8-backed)."""
    grid = (x.shape[0] // SUBLANES,)
    return pl.pallas_call(
        _apply_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(BLOCK, lambda i, s: (i, 0))],
            out_specs=pl.BlockSpec(BLOCK, lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int8),
        interpret=interpret,
    )(jnp.asarray([tau], jnp.float32), x)
