"""Pure-jnp oracle for the topk_mask kernel: identical 2-level
(log2 histogram -> linear refine) threshold selection, plus the exact
sort-based mask for accuracy assertions."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.topk_mask.topk_mask import N_BINS


def log2_taus(absmax):
    j = jnp.arange(N_BINS, dtype=jnp.float32)
    return absmax * 2.0 ** (-j / 2.0)


def linear_taus(lo, hi):
    j = jnp.arange(N_BINS, dtype=jnp.float32)
    return hi - (hi - lo) * j / (N_BINS - 1)


def select_tau_ref(x, k):
    """Same selection logic as ops.topk_mask_kernel, in pure jnp."""
    a = jnp.abs(x.reshape(-1).astype(jnp.float32))
    absmax = jnp.max(a)
    taus1 = log2_taus(absmax)
    counts1 = jnp.sum(a[None, :] >= taus1[:, None], axis=1) \
        .astype(jnp.float32)
    # first candidate with count >= k (taus descend; counts ascend)
    idx = jnp.argmax(counts1 >= k)
    hi = jnp.where(idx > 0, taus1[idx - 1], absmax)
    lo = taus1[idx]
    taus2 = linear_taus(lo, hi)
    counts2 = jnp.sum(a[None, :] >= taus2[:, None], axis=1) \
        .astype(jnp.float32)
    idx2 = jnp.argmax(counts2 >= k)
    tau = taus2[idx2]
    # degenerate guard: k >= n keeps everything
    return jnp.where(k >= a.size, jnp.zeros((), jnp.float32), tau)


def topk_mask_ref(x, k):
    tau = select_tau_ref(x, k)
    return jnp.abs(x.astype(jnp.float32)) >= tau


def topk_mask_exact(x, k):
    """Sort-based exact mask (accuracy yardstick)."""
    flat = jnp.abs(x.reshape(-1))
    _, idx = lax.top_k(flat, k)
    m = jnp.zeros(flat.shape, bool).at[idx].set(True)
    return m.reshape(x.shape)
