from repro.kernels.topk_mask import ops, ref  # noqa: F401
