"""Pure-jnp oracle for the fused_adam kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_adam_ref(scalars, w, g, m, v):
    """Identical math to the kernel, unfused.  scalars = f32[4]
    (lr_eff, b1, b2, eps_eff)."""
    lr, b1, b2, eps = scalars[0], scalars[1], scalars[2], scalars[3]
    gf = g.astype(jnp.float32)
    mf = b1 * m.astype(jnp.float32) + (1.0 - b1) * gf
    vf = b2 * v.astype(jnp.float32) + (1.0 - b2) * gf * gf
    upd = mf * jax.lax.rsqrt(vf + eps)
    w_new = (w.astype(jnp.float32) - lr * upd).astype(w.dtype)
    return w_new, mf.astype(m.dtype), vf.astype(v.dtype)
