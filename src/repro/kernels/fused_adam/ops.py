"""jit'd dispatch wrapper: arbitrary-shape pytree leaves -> 2D tiles ->
kernel; falls back to the jnp reference for tiny tensors where padding
overhead dominates.  interpret=True automatically off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_adam.fused_adam import (
    BLOCK, LANES, SUBLANES, fused_adam_2d)
from repro.kernels.fused_adam.ref import fused_adam_ref

_MIN_KERNEL_ELEMS = SUBLANES * LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _effective_scalars(h, count):
    """Fold bias correction into (lr_eff, eps_eff) — see kernel docstring."""
    lr = jnp.asarray(h.lr, jnp.float32)
    eps = jnp.asarray(h.eps, jnp.float32)
    if h.bias_correction:
        t = count.astype(jnp.float32) + 1.0
        c2 = 1.0 - h.beta2 ** t
        c1 = 1.0 - h.beta1 ** t
        lr = lr * jnp.sqrt(c2) / c1
        eps = eps * c2
    return jnp.stack([lr, jnp.asarray(h.beta1, jnp.float32),
                      jnp.asarray(h.beta2, jnp.float32), eps])


def fused_adam(w, g, m, v, h, count):
    """Drop-in replacement for optim.adam._adam_leaf (kernel path).

    NOTE on bias correction: the kernel computes the *uncorrected* m/v and
    folds correction into lr/eps, so the returned moments match the paper's
    Eqs. (4)-(5) exactly (as does the jnp path)."""
    scalars = _effective_scalars(h, count)
    n = w.size
    if n < _MIN_KERNEL_ELEMS:
        return fused_adam_ref(scalars, w, g, m, v)
    pad = (-n) % _MIN_KERNEL_ELEMS
    prep = lambda x: jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, LANES)
    w2, g2, m2, v2 = prep(w), prep(g), prep(m), prep(v)
    wo, mo, vo = fused_adam_2d(scalars, w2, g2, m2, v2,
                               interpret=_interpret())
    unprep = lambda x2, like: x2.reshape(-1)[:n].reshape(like.shape)
    return unprep(wo, w), unprep(mo, m), unprep(vo, v)
