"""Fused Adam update kernel (the paper's Eqs. 3-5) — one VMEM round-trip.

Unfused, each local epoch reads w,g,m,v and writes w,m,v through separate
HLO ops with f32 temporaries (the memory-roofline term of local training).
The kernel streams (8, 1024) tiles: per tile 4 loads + 3 stores, all
arithmetic in VREGs at f32.

Scalars (lr_eff, beta1, beta2, eps_eff) arrive via scalar prefetch (SMEM);
bias correction is folded into lr_eff/eps_eff by the ops.py wrapper:

    upd = m_hat / sqrt(v_hat + eps)
        = m * [sqrt(1-b2^t)/(1-b1^t)] / sqrt(v + eps*(1-b2^t))
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 1024          # block minor dim (multiple of 128)
SUBLANES = 8          # block major dim (f32 tile height)
BLOCK = (SUBLANES, LANES)


def _kernel(s_ref, w_ref, g_ref, m_ref, v_ref, wo_ref, mo_ref, vo_ref):
    lr = s_ref[0]
    b1 = s_ref[1]
    b2 = s_ref[2]
    eps = s_ref[3]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    v = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    upd = m * jax.lax.rsqrt(v + eps)
    wo_ref[...] = (w_ref[...].astype(jnp.float32) - lr * upd) \
        .astype(wo_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)
    vo_ref[...] = v.astype(vo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_adam_2d(scalars, w, g, m, v, *, interpret: bool = True):
    """w/g/m/v: (R, LANES) with R % SUBLANES == 0; scalars: f32[4] =
    [lr_eff, beta1, beta2, eps_eff].  Returns (w', m', v')."""
    R = w.shape[0]
    grid = (R // SUBLANES,)
    # index_map receives (grid indices..., scalar_ref) under scalar prefetch
    spec = pl.BlockSpec(BLOCK, lambda i, s: (i, 0))
    out_shape = (
        jax.ShapeDtypeStruct(w.shape, w.dtype),
        jax.ShapeDtypeStruct(m.shape, m.dtype),
        jax.ShapeDtypeStruct(v.shape, v.dtype),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec, spec, spec],
            out_specs=(spec, spec, spec),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(scalars, w, g, m, v)
