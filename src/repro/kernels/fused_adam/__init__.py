from repro.kernels.fused_adam import ops, ref  # noqa: F401
