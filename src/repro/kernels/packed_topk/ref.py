"""Pure-jnp oracles for the packed cohort-compression kernels.

The count oracles mirror the kernels' execution order exactly — a scan
over (8, 128)-blocks accumulating into an (L, N_BINS) carry, the jnp
rendering of the grid loop + VMEM accumulator — so the f32 addition
order (hence every count bit) matches the kernel, and the scan form is
also the efficient CPU stand-in the benchmark harness times (reduction
over the minor axis; no (n, N_BINS) materialization).

``refine_taus`` is the HOST half of packed selection: it turns the
launch-1 histogram into the per-segment linear-refine candidate rows
with the same op-for-op eager arithmetic as the per-leaf
``select_tau_kernel`` (argmax bracket, ``linear_taus``), which is what
makes the packed tau bitwise equal to the per-leaf tau.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.packed_topk.packed_topk import (
    BLOCK_ELEMS, LANES, N_BINS, SUBLANES)
from repro.kernels.topk_mask.ref import linear_taus


def _block_view(xp):
    """(R, LANES) packed buffer -> (nb, BLOCK_ELEMS) kernel-block rows."""
    return jnp.abs(xp.astype(jnp.float32)).reshape(-1, BLOCK_ELEMS)


def packed_hist_ref(xp, seg_ids, edges):
    """Oracle for ``packed_hist_2d`` / ``packed_hist_kernel``: per-block
    count of |x| >= edge_j accumulated into the block's segment row, in
    kernel block order."""
    a2 = _block_view(xp)
    L = edges.shape[0]

    def body(acc, blk):
        a_blk, seg = blk
        row = jnp.sum(edges[seg][:, None] <= a_blk[None, :], axis=1,
                      dtype=jnp.float32)
        return acc.at[seg].add(row), None

    acc, _ = lax.scan(body, jnp.zeros((L, N_BINS), jnp.float32),
                      (a2, seg_ids))
    return acc


def refine_taus(counts, edges, absmax, ks):
    """Per-segment linear-refine candidate rows from the histogram CDF.

    ``counts``/``edges``: (L, N_BINS); ``absmax``: length-L sequence of
    f32 scalars; ``ks``: (L,) f32.  Returns (L, N_BINS).  Deliberately a
    per-segment Python loop of SCALAR jnp ops — the identical expression
    sequence ``select_tau_kernel`` evaluates per leaf, so each candidate
    row is bitwise the per-leaf ``linear_taus(lo, hi)`` row (a batched
    rendering may fuse the multiply-subtract differently and drift by an
    ulp, which would break the packed==per-leaf tau guarantee)."""
    rows = []
    for s in range(counts.shape[0]):
        idx = jnp.argmax(counts[s] >= ks[s])
        hi = jnp.where(idx > 0, edges[s][idx - 1], absmax[s])
        lo = edges[s][idx]
        rows.append(linear_taus(lo, hi))
    return jnp.stack(rows)


def _pick_taus(taus2, c2, ks, ns):
    """First candidate whose count reaches k, per segment (degenerate
    k >= n keeps everything: tau = 0, count = n)."""
    idx2 = jnp.argmax(c2 >= ks[:, None], axis=1)
    tau = jnp.take_along_axis(taus2, idx2[:, None], 1)[:, 0]
    cnt = jnp.take_along_axis(c2, idx2[:, None], 1)[:, 0]
    tau = jnp.where(ks >= ns, jnp.zeros((), jnp.float32), tau)
    cnt = jnp.where(ks >= ns, ns, cnt)
    return tau, cnt


def _cast(value_dtype, x):
    if value_dtype is None:
        return x
    vdt = jnp.dtype(value_dtype)
    return x.astype(vdt).astype(x.dtype)


def packed_apply_ef_ref(taus2, seg_ids, ks, ns, streams, sp=None, *,
                        with_residual: bool = True, value_dtype=None):
    """Oracle for ``packed_apply_2d`` / ``packed_apply_ef``: refine-count
    (same scan as the kernel's sweep 0), tau pick, then the composed
    mask/cast/residual elementwise ops."""
    streams = tuple(streams)
    score = streams[0] if sp is None else sp
    c2 = packed_hist_ref(score, seg_ids, taus2)
    tau, cnt = _pick_taus(taus2, c2, ks, ns)
    tau_e = tau[seg_ids].repeat(BLOCK_ELEMS).reshape(score.shape[0], LANES)
    keep = jnp.abs(score.astype(jnp.float32)) >= tau_e
    outs = []
    for x in streams:
        outs.append(jnp.where(keep, _cast(value_dtype, x),
                              jnp.zeros((), x.dtype)))
    if with_residual:
        x0, s0 = streams[0], outs[0]
        outs.append((x0.astype(jnp.float32) - s0.astype(jnp.float32))
                    .astype(x0.dtype))
    return tuple(outs) + (tau.reshape(-1, 1), cnt.reshape(-1, 1))


def packed_mask_apply_ref(taus2, seg_ids, ks, ns, xp, *,
                          with_residual: bool = True, value_dtype=None):
    """Single-stream oracle (independent compress: each stream is its
    own score)."""
    return packed_apply_ef_ref(taus2, seg_ids, ks, ns, (xp,),
                               with_residual=with_residual,
                               value_dtype=value_dtype)
