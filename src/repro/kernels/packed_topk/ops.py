"""Public packed-cohort ops: whole-model compress in TWO Pallas launches.

    c1                  = packed_hist_kernel(score_p, seg_ids, edges)
    taus2               = ref.refine_taus(c1, edges, absmax, ks)   # host
    sW, sM, sV, err, tau, cnt = packed_apply_ef(
        taus2, seg_ids, ks, ns, dW_p, dM_p, dV_p)

vs 4 launches PER LEAF on the per-leaf path (absmax, two count passes,
fused apply).  The buffers are (R, 128) packed cohorts built by
``core/sparsify.PackedLayout``; ``seg_ids`` maps each (8, 128) block to
its tau segment (one per leaf for scope="per_tensor", a single segment
for scope="global"), so both scopes are the same two launches.

``packed_mask_apply`` is the single-stream variant for the independent
(three-mask) compressor, which packs all of dW ++ dM ++ dV into ONE
buffer whose segments each select their own tau — still two launches
for all three trees.

tau semantics are IDENTICAL to ``topk_mask.select_tau_kernel`` — same
candidate construction, same first-count->=k pick, same degenerate
k >= n guard — so the ``overselect_bound`` contract carries over
unchanged, and tau (hence every masked value and the EF residual) is
bitwise equal to the per-leaf path's.  Oracles: ref.py; parity:
tests/test_kernels.py; layout + drivers: core/sparsify.py; contract
walkthrough: docs/kernels.md.
"""
from __future__ import annotations

import jax

from repro.kernels.packed_topk.packed_topk import (
    BLOCK_ELEMS, LANES, N_BINS, SUBLANES, packed_apply_2d, packed_hist_2d)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def packed_hist_kernel(xp, seg_ids, edges):
    """Segmented 32-bin histogram over a packed (R, 128) buffer: counts
    of |x| >= edges[seg, j] per segment.  ONE launch; selection's only
    full-data Pallas pass (the refine counts ride in the apply launch).

    ``xp``: (R, LANES) tile-aligned packed cohort; ``seg_ids``: (R //
    SUBLANES,) int32 block->segment map; ``edges``: (L, N_BINS) f32
    descending candidates per segment.  Returns (L, N_BINS) f32 counts.
    """
    return packed_hist_2d(xp, seg_ids, edges, interpret=_interpret())


def packed_apply_ef(taus2, seg_ids, ks, ns, dw, dm, dv, score=None, *,
                    with_residual: bool = True, value_dtype=None):
    """Fused refine-count + tau-pick + shared-mask apply.  ONE launch.

    Sweep 0 counts |score| (|dW| when ``score is None`` — the ssm_w
    rule) against the prefetched ``taus2`` (L, N_BINS) refine
    candidates; sweep 1 picks each segment's tau (first count >= k) and
    streams ``where(keep, cast(x), 0)`` over all three deltas plus the
    optional error-feedback residual ``dw - sw``, exactly
    ``ssm_apply_ef``'s arithmetic.  ``ks``/``ns``: (L,) f32 per-segment
    k and true (unpadded) element counts.

    Returns ``(sw, sm, sv, [err], taus, counts)`` with ``taus``/
    ``counts`` of shape (L, 1).
    """
    return packed_apply_2d(taus2, seg_ids, ks, ns, (dw, dm, dv), score,
                           with_residual=with_residual,
                           value_dtype=value_dtype, interpret=_interpret())


def packed_mask_apply(taus2, seg_ids, ks, ns, x, *,
                      with_residual: bool = True, value_dtype=None):
    """Single-stream packed compress (independent masks: every segment's
    score is the stream itself).  ONE launch.

    Returns ``(sx, [err], taus, counts)``; ``err`` is ``x - sx`` (the
    caller keeps only the dW segments' rows of it).
    """
    return packed_apply_2d(taus2, seg_ids, ks, ns, (x,), None,
                           with_residual=with_residual,
                           value_dtype=value_dtype, interpret=_interpret())
