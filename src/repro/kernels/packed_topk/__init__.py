from repro.kernels.packed_topk import ops, ref  # noqa: F401
