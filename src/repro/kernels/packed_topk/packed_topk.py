"""Packed multi-leaf threshold selection + fused apply — 2 launches/cohort.

The per-leaf hot path (kernels/topk_mask + kernels/ssm_apply) costs 4
Pallas launches PER PYTREE LEAF (absmax, 2 count passes, fused apply): a
whisper-base client pays ~100 kernel round trips per round.  These
kernels batch every leaf of the (score, dW, dM, dV) cohort through ONE
tile-aligned packed buffer (layout: core/sparsify.PackedLayout) so the
whole-model compress is exactly TWO launches:

  launch 1 (``_hist_kernel``)  — segmented log2 histogram: each (8, 128)
      block accumulates count(|x| >= edge_j) for its segment's 32
      scalar-prefetch-indexed bin edges into a VMEM-resident (L, 32)
      accumulator (rows = segments; one row for scope="global").
  host refine (no launch)      — the CDF bracket (first bin with count
      >= k) and the 32 linear-refine candidates are derived from the
      (L, 32) histogram with the SAME eager jnp arithmetic as the
      per-leaf ``select_tau_kernel``, so the candidate taus are
      bit-identical to the per-leaf path's.
  launch 2 (``_make_apply_kernel``) — a (2, nb) two-sweep grid: sweep 0
      counts |score| against the prefetched refine candidates into VMEM
      scratch; sweep 1 PICKS tau per segment from the completed counts
      (a select, not arithmetic — so tau is bit-exact vs per-leaf) and
      streams mask-apply x3 + ``value_dtype`` wire cast + error-feedback
      residual, extending kernels/ssm_apply's fused structure.

Why the tau *pick* lives in the kernel: deriving tau needs the refine
counts, which need a full pass over the data — folding that pass into
the apply launch (sweep 0) is what collapses selection+apply to one
launch without giving up the 3-pass algorithm's ``overselect_bound``
contract.  The w/m/v streams use a ``(i * p, 0)`` index map so sweep 0
re-fetches only block 0 (revisited = free) instead of streaming the
whole tensor twice; only the score stream is read in both sweeps.

Padding is inert: per-leaf zero padding never counts (all candidate
edges are > 0 unless a segment is all-zero, where tau = 0 anyway) and
never survives the mask for tau > 0.  Counts accumulate in f32 — exact
integers below 2^24 per block, add-chain error << 1 count to d <= 2^40
(same argument as kernels/topk_mask).  Contract and launch accounting:
docs/kernels.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# One packed block = the (8, 128) f32 min tile; per-leaf padding rounds
# to BLOCK_ELEMS, so small leaves waste at most one tile each (vs one
# (8, 1024) super-tile per leaf on the per-leaf path).
LANES = 128
SUBLANES = 8
BLOCK = (SUBLANES, LANES)
BLOCK_ELEMS = SUBLANES * LANES
N_BINS = 32


def _hist_kernel(seg_ref, e_ref, x_ref, c_ref):
    i = pl.program_id(0)
    seg = seg_ref[i]
    a = jnp.abs(x_ref[...].astype(jnp.float32))
    edges = e_ref[...]                               # (1, N_BINS)

    @pl.when(i == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    # unrolled over the N_BINS candidates: VPU reductions in registers,
    # then one accumulate into this segment's histogram row
    cols = [jnp.sum((a >= edges[0, j]).astype(jnp.float32))
            for j in range(N_BINS)]
    row = jnp.stack(cols).reshape(1, N_BINS)
    cur = pl.load(c_ref, (pl.ds(seg, 1), slice(None)))
    pl.store(c_ref, (pl.ds(seg, 1), slice(None)), cur + row)


@functools.partial(jax.jit, static_argnames=("interpret",))
def packed_hist_2d(xp, seg_ids, edges, *, interpret: bool = True):
    """Segmented histogram over a packed (R, LANES) buffer.

    ``seg_ids``: (nb,) int32 segment of each (8, 128) block (scalar
    prefetch — it also drives the edge-row BlockSpec index map);
    ``edges``: (L, N_BINS) descending per-segment candidates.  Returns
    (L, N_BINS) f32 counts of |x| >= edge_j per segment.  ONE launch.
    """
    nb = xp.shape[0] // SUBLANES
    L = edges.shape[0]
    return pl.pallas_call(
        _hist_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb,),
            in_specs=[
                # one segment's edge row, picked by the prefetched seg id
                pl.BlockSpec(  # repro-lint: disable=pallas-contract
                    (1, N_BINS), lambda i, seg: (seg[i], 0)),
                pl.BlockSpec(BLOCK, lambda i, seg: (i, 0)),
            ],
            # deliberately sub-tile: the (L, N_BINS) histogram rows are
            # revisited every grid step, not streamed
            out_specs=pl.BlockSpec(  # repro-lint: disable=pallas-contract
                (L, N_BINS), lambda i, seg: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((L, N_BINS), jnp.float32),
        interpret=interpret,
    )(seg_ids, edges, xp)


def _make_apply_kernel(n_streams: int, has_score: bool,
                       with_residual: bool, value_dtype):
    """Two-sweep fused kernel body.  Static shape:
    scalar prefetch  seg_ids, ks, ns
    inputs           taus2 row, [score?], x_0 .. x_{n_streams-1}
    outputs          s_0 .. s_{n_streams-1}, [err?], taus, counts
    scratch          (L, N_BINS) refine-count accumulator

    Sweep p=0 counts |score| >= taus2_j into the scratch row of this
    block's segment; sweep p=1 picks tau (first candidate whose count
    reaches k — exactly the per-leaf selection rule, ties included),
    then applies keep/cast/residual to every stream.  ``err`` is the
    residual of stream 0 (dW), matching ssm_apply_ef's contract."""
    vdt = None if value_dtype is None else jnp.dtype(value_dtype)

    def cast(x):
        return x if vdt is None else x.astype(vdt).astype(x.dtype)

    def kernel(seg_ref, ks_ref, ns_ref, t2_ref, *refs):
        *io, c2_ref = refs
        if has_score:
            score_ref, io = io[0], io[1:]
        ins, outs = io[:n_streams], io[n_streams:]
        if not has_score:
            score_ref = ins[0]
        p = pl.program_id(0)
        i = pl.program_id(1)
        seg = seg_ref[i]
        a = jnp.abs(score_ref[...].astype(jnp.float32))
        taus2 = t2_ref[...]                          # (1, N_BINS)

        @pl.when((p == 0) & (i == 0))
        def _init():
            c2_ref[...] = jnp.zeros_like(c2_ref)

        @pl.when(p == 0)
        def _count():
            cols = [jnp.sum((a >= taus2[0, j]).astype(jnp.float32))
                    for j in range(N_BINS)]
            row = jnp.stack(cols).reshape(1, N_BINS)
            cur = pl.load(c2_ref, (pl.ds(seg, 1), slice(None)))
            pl.store(c2_ref, (pl.ds(seg, 1), slice(None)), cur + row)

        @pl.when(p == 1)
        def _apply():
            k = ks_ref[seg]
            n = ns_ref[seg]
            c2 = pl.load(c2_ref, (pl.ds(seg, 1), slice(None)))
            iota = lax.broadcasted_iota(jnp.int32, (1, N_BINS), 1)
            idx2 = jnp.argmax(c2 >= k)
            # scalar pick from a (1, N_BINS) row — a select, not
            # arithmetic, so tau is bitwise one of the prefetched
            # candidates (the bit-exactness hinge; see module docstring)
            sel = lambda row, j: jnp.sum(jnp.where(iota == j, row, 0.0))
            tau = sel(taus2, idx2)
            cnt = sel(c2, idx2)
            tau = jnp.where(k >= n, jnp.zeros((), jnp.float32), tau)
            cnt = jnp.where(k >= n, n, cnt)

            keep = a >= tau
            x0 = ins[0][...]
            zero = jnp.zeros((), x0.dtype)
            s0 = jnp.where(keep, cast(x0), zero)
            outs[0][...] = s0
            for t in range(1, n_streams):
                outs[t][...] = jnp.where(
                    keep, cast(ins[t][...]),
                    jnp.zeros((), ins[t].dtype))
            nxt = n_streams
            if with_residual:
                outs[nxt][...] = (x0.astype(jnp.float32)
                                  - s0.astype(jnp.float32)).astype(x0.dtype)
                nxt += 1
            pl.store(outs[nxt], (pl.ds(seg, 1), pl.ds(0, 1)),
                     tau.reshape(1, 1))
            pl.store(outs[nxt + 1], (pl.ds(seg, 1), pl.ds(0, 1)),
                     cnt.reshape(1, 1))

    return kernel


@functools.partial(jax.jit, static_argnames=("with_residual", "value_dtype",
                                             "interpret"))
def packed_apply_2d(taus2, seg_ids, ks, ns, streams, sp=None, *,
                    with_residual: bool = True, value_dtype=None,
                    interpret: bool = True):
    """Two-sweep fused refine-count + tau-pick + mask-apply.  ONE launch.

    ``streams``: tuple of packed (R, LANES) buffers sharing the mask
    (the (dW, dM, dV) triple for the shared-mask compress; a 1-tuple
    for the independent compress, where every stream is its own score).
    ``sp``: optional packed score buffer (non-ssm_w rules).  Returns
    ``(*sparse_streams, [err], taus (L, 1), counts (L, 1))``; ``err``
    is stream 0's error-feedback residual.
    """
    streams = tuple(streams)
    n_streams = len(streams)
    nb = streams[0].shape[0] // SUBLANES
    L = ks.shape[0]
    has_score = sp is not None
    # the count sweep (p=0) reads only the score stream; w/m/v index
    # maps collapse to block 0 there so their HBM traffic happens once
    stream_spec = pl.BlockSpec(BLOCK, lambda p, i, *s: (i, 0))
    lazy_spec = pl.BlockSpec(BLOCK, lambda p, i, *s: (i * p, 0))
    row_spec = pl.BlockSpec(  # repro-lint: disable=pallas-contract
        (L, 1), lambda p, i, *s: (0, 0))
    ins = ([sp] if has_score else []) + list(streams)
    in_specs = [
        pl.BlockSpec(  # repro-lint: disable=pallas-contract
            (1, N_BINS), lambda p, i, seg, *s: (seg[i], 0)),
    ]
    if has_score:
        in_specs += [stream_spec] + [lazy_spec] * n_streams
    else:
        in_specs += [stream_spec] + [lazy_spec] * (n_streams - 1)
    n_data_out = n_streams + (1 if with_residual else 0)
    out_specs = tuple([lazy_spec] * n_data_out + [row_spec, row_spec])
    out_shape = tuple(
        jax.ShapeDtypeStruct(t.shape, t.dtype)
        for t in streams + ((streams[0],) if with_residual else ())
    ) + (jax.ShapeDtypeStruct((L, 1), jnp.float32),) * 2
    return pl.pallas_call(
        _make_apply_kernel(n_streams, has_score, with_residual, value_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(2, nb),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((L, N_BINS), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(seg_ids, ks, ns, taus2, *ins)
