"""Public wire-format pack/unpack ops: scheme-level encodings over the
packed (R, 128) cohort buffer.

Three encoding families, one kernel pair (``pack_words_2d`` /
``unpack_words_2d`` with static code width b):

* ``pack_mask_bits`` / ``unpack_mask_bits`` — b=1 bitmap of a sparse
  support (FedAdam-SSM's shared-mask wire: 1 bit/param + the compacted
  value stream, Section IV).
* ``pack_sign_scale`` / ``unpack_sign_scale`` — b=1 sign bitplane plus
  one f32 scale per 1024-element block (1-bit Adam, arXiv 2109.05109).
  Exact for ``quantize.sign_quant`` carriers: every block is two-valued
  ``+-scale`` so ``max|block|`` recovers the scale bitwise.
* ``pack_bbit`` / ``unpack_bbit`` — b-bit two's-offset codes (b in
  {2, 4, 8}) from ``quantize.uniform_encode`` (Efficient-Adam, arXiv
  2205.02719); scales travel beside the words in the WirePayload.

All scheme-specific arithmetic (sign extraction, offset shift, block
scales) is elementwise jnp around the single word-packing launch; the
packed rows are the ONLY buffer that crosses the client axis.  Oracles:
ref.py; parity: tests/test_kernels.py; payload layout: core/wire.py and
docs/wire.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wirepack.wirepack import (
    CODE_SUBLANES, LANES, SUPPORTED_BITS, WORD_BITS, pack_words_2d,
    unpack_words_2d)

#: Elements per f32 scale block (must match core/sparsify.PACK_BLOCK_ELEMS
#: so packed-buffer blocks align with quantizer blocks; wire.py asserts).
SCALE_BLOCK = 1024


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_mask_bits(support):
    """(R, LANES) 0/1 support (R % 32 == 0) -> (R/32, LANES) uint32
    bitmap words, 1 bit per parameter.  ONE launch."""
    return pack_words_2d(support.astype(jnp.int32), bits=1,
                         interpret=_interpret())


def unpack_mask_bits(words):
    """Inverse of :func:`pack_mask_bits`: uint32 bitmap words back to the
    (R, LANES) int32 0/1 support.  ONE launch."""
    return unpack_words_2d(words, bits=1, interpret=_interpret())


def pack_sign_scale(xp):
    """(R, LANES) f32 carrier -> ``(words, scales)``: (R/32, LANES)
    uint32 sign-bitplane words (bit = x >= 0) and (R*LANES/1024,) f32
    per-block ``max|x|`` scales.  ONE launch plus a jnp reduction."""
    x = xp.astype(jnp.float32)
    bits = (x >= 0).astype(jnp.int32)
    scales = jnp.max(jnp.abs(x).reshape(-1, SCALE_BLOCK), axis=1)
    return pack_words_2d(bits, bits=1, interpret=_interpret()), scales


def unpack_sign_scale(words, scales):
    """Inverse of :func:`pack_sign_scale`: reconstruct the two-valued
    carrier ``where(bit, +scale, -scale)`` of shape (R, LANES)."""
    bits = unpack_words_2d(words, bits=1, interpret=_interpret())
    s = jnp.broadcast_to(scales[:, None],
                         (scales.shape[0], SCALE_BLOCK)).reshape(bits.shape)
    return jnp.where(bits == 1, s, -s)


def pack_bbit(codes, bits: int):
    """(R, LANES) int32 symmetric codes in [-qmax, qmax] (qmax =
    2**(bits-1) - 1) -> (R*bits/32, LANES) uint32 words of unsigned
    offset codes ``code + qmax``.  ONE launch."""
    qmax = (1 << (bits - 1)) - 1
    return pack_words_2d(codes + qmax, bits=bits, interpret=_interpret())


def unpack_bbit(words, bits: int):
    """Inverse of :func:`pack_bbit`: words back to (R, LANES) int32
    signed codes.  ONE launch."""
    qmax = (1 << (bits - 1)) - 1
    return unpack_words_2d(words, bits=bits, interpret=_interpret()) - qmax
