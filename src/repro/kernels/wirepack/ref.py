"""Pure-jnp oracles for the wire-format pack/unpack kernels.

``pack_words_ref`` / ``unpack_words_ref`` are the vectorized rendering
of the kernel's per-block loop: the (R, 128) code buffer viewed as
(nb, b, T, 128) row groups, one uint32 multiply-accumulate over the T
axis.  All arithmetic is integer (multiplies by static powers of two),
so oracle and kernel agree bitwise — these doubles as the CPU reference
transport in ``core/wire.py``.

The scheme-level oracles repeat the ops-layer jnp conversions verbatim
(sign extraction, block scales, offset shift) around the word oracles.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.wirepack.wirepack import (
    CODE_SUBLANES, LANES, SUPPORTED_BITS, WORD_BITS)

SCALE_BLOCK = 1024


def _weights(bits: int):
    T = WORD_BITS // bits
    return jnp.asarray([1 << (t * bits) for t in range(T)], jnp.uint32)


def pack_words_ref(codes, bits: int):
    """Oracle for ``pack_words_2d``: (R, LANES) unsigned int32 codes ->
    (R*bits/32, LANES) uint32 words."""
    T = WORD_BITS // bits
    nb = codes.shape[0] // CODE_SUBLANES
    u = codes.astype(jnp.uint32).reshape(nb, bits, T, LANES)
    w = jnp.sum(u * _weights(bits)[None, None, :, None], axis=2,
                dtype=jnp.uint32)
    return w.reshape(nb * bits, LANES)


def unpack_words_ref(words, bits: int):
    """Oracle for ``unpack_words_2d``: words back to int32 codes."""
    T = WORD_BITS // bits
    nb = words.shape[0] // bits
    mask = jnp.uint32((1 << bits) - 1)
    w = words.reshape(nb, bits, 1, LANES)
    shifts = jnp.asarray([t * bits for t in range(T)], jnp.uint32)
    u = (w >> shifts[None, None, :, None]) & mask
    return u.astype(jnp.int32).reshape(nb * CODE_SUBLANES, LANES)


def pack_mask_bits_ref(support):
    return pack_words_ref(support.astype(jnp.int32), 1)


def unpack_mask_bits_ref(words):
    return unpack_words_ref(words, 1)


def pack_sign_scale_ref(xp):
    x = xp.astype(jnp.float32)
    bits = (x >= 0).astype(jnp.int32)
    scales = jnp.max(jnp.abs(x).reshape(-1, SCALE_BLOCK), axis=1)
    return pack_words_ref(bits, 1), scales


def unpack_sign_scale_ref(words, scales):
    bits = unpack_words_ref(words, 1)
    s = jnp.broadcast_to(scales[:, None],
                         (scales.shape[0], SCALE_BLOCK)).reshape(bits.shape)
    return jnp.where(bits == 1, s, -s)


def pack_bbit_ref(codes, bits: int):
    qmax = (1 << (bits - 1)) - 1
    return pack_words_ref(codes + qmax, bits)


def unpack_bbit_ref(words, bits: int):
    qmax = (1 << (bits - 1)) - 1
    return unpack_words_ref(words, bits) - qmax
