from repro.kernels.wirepack import ops, ref  # noqa: F401
