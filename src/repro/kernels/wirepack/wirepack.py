"""Bit-pack / bit-unpack Pallas kernels for the wire format.

The uplink wire format (core/wire.py) ships b-bit unsigned codes packed
into uint32 words: mask bitmaps (b=1, FedAdam-SSM Section IV), sign
bitplanes (b=1, 1-bit Adam, arXiv 2109.05109), and b-bit quantizer
codes (b in {2, 4, 8}, Efficient-Adam, arXiv 2205.02719).  These two
kernels are the only data-touching passes — everything scheme-specific
(code construction, scales, value compaction) is cheap jnp around them.

Layout.  Input is the (R, 128) packed cohort buffer convention of
``core/sparsify.PackedLayout`` with R a multiple of 32 (one grid block =
32 sublanes x 128 lanes = 4096 codes).  Each group of T = 32 // b code
rows collapses into one word row::

    word[q, c] = sum_t code[q*T + t, c] * 2**(t*b)      (uint32)

so a (32, 128) code block becomes a (b, 128) word block and the word
buffer is exactly ``R * b / 32`` rows — bits on the wire == b bits per
code, by construction.  Codes must already be unsigned in [0, 2**b);
the ops layer owns the signed-offset / sign-bit conversions.

Words accumulate in uint32: at b=8 the top code contributes
``255 << 24``, which overflows int32 but is exact in uint32 (all
multiplies are by static powers of two, so packing is lossless and
``unpack(pack(x)) == x`` bitwise).  Oracles: ref.py; parity:
tests/test_kernels.py; format spec: docs/wire.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
#: Rows per grid block: 32 code rows -> ``bits`` word rows.
CODE_SUBLANES = 32
#: Word size on the wire.
WORD_BITS = 32
#: Supported code widths (32 must divide evenly into b-bit lanes).
SUPPORTED_BITS = (1, 2, 4, 8)


def _check_bits(bits: int) -> int:
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {bits}")
    return WORD_BITS // bits


def _make_pack_kernel(bits: int):
    T = _check_bits(bits)

    def kernel(x_ref, w_ref):
        x = x_ref[...].astype(jnp.uint32)            # (32, LANES)
        rows = []
        for q in range(bits):
            acc = jnp.zeros((1, LANES), jnp.uint32)
            for t in range(T):
                r = q * T + t
                acc = acc + x[r:r + 1, :] * jnp.uint32(1 << (t * bits))
            rows.append(acc)
        w_ref[...] = jnp.concatenate(rows, axis=0)   # (bits, LANES)

    return kernel


def _make_unpack_kernel(bits: int):
    T = _check_bits(bits)
    mask = (1 << bits) - 1

    def kernel(w_ref, x_ref):
        w = w_ref[...]                               # (bits, LANES) uint32
        rows = []
        for q in range(bits):
            wq = w[q:q + 1, :]
            for t in range(T):
                rows.append(((wq >> jnp.uint32(t * bits)) & jnp.uint32(mask))
                            .astype(jnp.int32))
        x_ref[...] = jnp.concatenate(rows, axis=0)   # (32, LANES)

    return kernel


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack_words_2d(codes, *, bits: int, interpret: bool = True):
    """Pack an (R, LANES) int32 unsigned-code buffer (R % 32 == 0, codes
    in [0, 2**bits)) into an (R * bits / 32, LANES) uint32 word buffer.
    ONE launch."""
    _check_bits(bits)
    nb = codes.shape[0] // CODE_SUBLANES
    return pl.pallas_call(
        _make_pack_kernel(bits),
        grid=(nb,),
        in_specs=[pl.BlockSpec((CODE_SUBLANES, LANES), lambda i: (i, 0))],
        # word blocks are (bits, LANES) — deliberately sub-tile for
        # bits < 8: the packed rows are written once, never revisited
        out_specs=pl.BlockSpec(  # repro-lint: disable=pallas-contract
            (bits, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * bits, LANES), jnp.uint32),
        interpret=interpret,
    )(codes)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def unpack_words_2d(words, *, bits: int, interpret: bool = True):
    """Exact inverse of :func:`pack_words_2d`: (R * bits / 32, LANES)
    uint32 words back to (R, LANES) int32 unsigned codes.  ONE launch."""
    _check_bits(bits)
    nb = words.shape[0] // bits
    return pl.pallas_call(
        _make_unpack_kernel(bits),
        grid=(nb,),
        in_specs=[pl.BlockSpec(  # repro-lint: disable=pallas-contract
            (bits, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((CODE_SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * CODE_SUBLANES, LANES),
                                       jnp.int32),
        interpret=interpret,
    )(words)
