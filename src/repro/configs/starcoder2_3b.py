"""StarCoder2-3B — dense GQA code model.  [arXiv:2402.19173]

Assigned spec: 30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288,
vocab=49152.  RoPE; window=4096 long-context variant as for the 7B.
"""
from repro.configs.base import ArchConfig, AttentionSpec, LayerSpec, register


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=24, num_kv_heads=2, head_dim=128,
                         rope_theta=1_000_000.0)
    layer = LayerSpec(kind="attn", attention=attn, d_ff=12288, gated_mlp=False)
    return ArchConfig(
        name="starcoder2-3b",
        family="dense",
        d_model=3072,
        vocab_size=49152,
        layer_pattern=(layer,),
        pattern_repeats=30,
        source="arXiv:2402.19173 (StarCoder2)",
        long_context_window=4096,
    )
