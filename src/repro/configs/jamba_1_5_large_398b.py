"""Jamba-1.5-Large 398B — hybrid Mamba+attention MoE.  [arXiv:2403.19887]

Assigned spec: 72L, d_model=8192, 64 heads (GQA kv=8), expert d_ff=24576,
vocab=65536, MoE 16 experts top-2, attention:mamba interleave 1:7, MoE every
other layer.  Pattern of 8 layers (attention at index 4, MoE on odd
indices), repeated 9x.  The released model uses Mamba-1 mixers; we implement
the Mamba-2/SSD formulation throughout (TPU-friendly chunked matmul scan) —
noted hardware adaptation.
"""
from repro.configs.base import (
    ArchConfig, AttentionSpec, LayerSpec, MoESpec, SSMSpec, register,
)


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=64, num_kv_heads=8, head_dim=128,
                         rope_theta=10000.0)
    ssm = SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=128,
                  chunk_size=256)
    moe = MoESpec(num_experts=16, top_k=2, d_ff=24576)
    d_ff_dense = 24576

    def layer(i: int) -> LayerSpec:
        kind = "attn" if i == 4 else "ssm"
        if i % 2 == 1:
            return LayerSpec(kind=kind,
                             attention=attn if kind == "attn" else None,
                             ssm=ssm if kind == "ssm" else None,
                             moe=moe)
        return LayerSpec(kind=kind,
                         attention=attn if kind == "attn" else None,
                         ssm=ssm if kind == "ssm" else None,
                         d_ff=d_ff_dense)

    pattern = tuple(layer(i) for i in range(8))
    return ArchConfig(
        name="jamba-1-5-large-398b",
        family="hybrid",
        d_model=8192,
        vocab_size=65536,
        layer_pattern=pattern,
        pattern_repeats=9,
        max_seq_len=262144,
        source="arXiv:2403.19887 (Jamba)",
        long_context_window=4096,   # the lone attention layer windows at 500k
    )
