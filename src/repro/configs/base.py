"""Architecture config system.

Every assigned architecture is expressed as an :class:`ArchConfig` — a
declarative description of a (possibly heterogeneous) decoder stack that the
model builder in :mod:`repro.models` turns into parameters + forward
functions.  Layer heterogeneity (Jamba's 1:7 attention:mamba interleave,
Gemma-3's 5:1 local:global pattern) is expressed as a repeating
``layer_pattern`` of :class:`LayerSpec` entries; the stack is built as a
``lax.scan`` over pattern repeats so the lowered HLO stays O(pattern), not
O(depth).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer-level spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Self-attention flavour for one layer."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window size; None = full
    # DeepSeek-style Multi-head Latent Attention (low-rank joint KV).
    kv_lora_rank: Optional[int] = None
    q_lora_rank: Optional[int] = None
    causal: bool = True

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank is not None


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts FFN flavour."""

    num_experts: int
    top_k: int
    d_ff: int                              # per-expert hidden width
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 (SSD) mixer flavour."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def num_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer in the repeating pattern."""

    kind: str                              # "attn" | "ssm"
    attention: Optional[AttentionSpec] = None
    ssm: Optional[SSMSpec] = None
    # FFN: exactly one of d_ff (dense) / moe is set; both None => no FFN
    # (Mamba-2 blocks are mixer-only).
    d_ff: Optional[int] = None
    moe: Optional[MoESpec] = None
    gated_mlp: bool = True                 # SwiGLU (3 mats) vs GELU (2 mats)


# ---------------------------------------------------------------------------
# Architecture-level config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Optional encoder stack (Whisper).  Frontend is a stub: inputs are
    precomputed frame embeddings of shape (batch, src_len, d_model)."""

    num_layers: int
    num_heads: int
    src_len: int                          # fixed source length (1500 for whisper)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                            # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    vocab_size: int
    layer_pattern: Tuple[LayerSpec, ...]   # repeated pattern_repeats times
    pattern_repeats: int
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    encoder: Optional[EncoderSpec] = None  # enc-dec archs (whisper)
    # VLM/audio frontends are stubs: when True, the model consumes
    # precomputed embeddings for a prefix of the sequence.
    stub_frontend: bool = False
    stub_frontend_tokens: int = 0          # e.g. image patch tokens
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    # citation for the source of the numbers
    source: str = ""
    # set for archs whose *default* is full attention but which we also ship
    # as a sliding-window variant for long-context serving
    long_context_window: Optional[int] = None
    # long_500k strategy: "window_all" rings every full-attention layer at
    # long_context_window; "mixed" keeps native-window layers ringed but
    # serves no-window (global) layers with a full sequence-sharded cache
    # (split-KV decode).
    long_strategy: str = "window_all"

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.layer_pattern) * self.pattern_repeats

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table
        shards evenly on any mesh axis (standard production padding; the
        analytic param_count stays source-faithful and unpadded)."""
        return -(-self.vocab_size // 256) * 256

    def supports_long_decode(self) -> bool:
        """True if a 500k-token decode is meaningful for this config:
        every attention layer must be windowed/MLA-free-running or the
        arch declares a long-context window variant, or it is SSM-only."""
        if self.encoder is not None:
            return False                  # whisper: decoder capped by design
        for spec in self.layer_pattern:
            if spec.kind == "attn":
                a = spec.attention
                if a.window is None and self.long_context_window is None:
                    return False
        return True

    # -- parameter counting (analytic; used by roofline + tests) -------
    def param_count(self) -> int:
        d = self.d_model
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # lm head
        for spec in self.layer_pattern:
            total += self._layer_params(spec) * self.pattern_repeats
        total += d                                       # final norm
        if self.encoder is not None:
            e = self.encoder
            # encoder self-attn + ffn (d_ff = 4d convention for whisper)
            enc_layer = 4 * d * d + 2 * d * (4 * d) + 4 * d
            total += e.num_layers * enc_layer + d
            # decoder cross-attention adds 4 d^2 per decoder layer,
            # counted in _layer_params via has-encoder flag handled here:
            total += self.num_layers * 4 * d * d
        return total

    def _layer_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        n = 0
        if spec.kind == "attn":
            a = spec.attention
            if a.is_mla:
                if a.q_lora_rank:
                    n += d * a.q_lora_rank
                    n += a.q_lora_rank * a.num_heads * a.head_dim
                else:
                    n += d * a.num_heads * a.head_dim
                n += d * a.kv_lora_rank                       # kv down-proj
                n += a.kv_lora_rank * a.num_heads * 2 * a.head_dim  # up-proj
                n += a.num_heads * a.head_dim * d             # o
            else:
                n += d * a.num_heads * a.head_dim          # q
                n += 2 * d * a.num_kv_heads * a.head_dim   # k,v
                n += a.num_heads * a.head_dim * d          # o
            n += 2 * d                                     # norms
        elif spec.kind == "ssm":
            s = spec.ssm
            d_inner = s.expand * d
            nheads = s.num_heads(d)
            n += d * (2 * d_inner + 2 * s.d_state + nheads)   # in_proj (zxbcdt)
            n += s.d_conv * (d_inner + 2 * s.d_state)         # conv
            n += d_inner * d                                  # out_proj
            n += 3 * nheads + d_inner                         # A, D, dt_bias, norm-ish
            n += d                                            # pre-norm
        if spec.d_ff:
            mats = 3 if spec.gated_mlp else 2
            n += mats * d * spec.d_ff + d                     # mlp + norm
        if spec.moe:
            m = spec.moe
            n += d * m.num_experts                            # router
            n += m.num_experts * 3 * d * m.d_ff
            if m.num_shared_experts:
                n += m.num_shared_experts * 3 * d * m.shared_d_ff
            n += d
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k routing)."""
        d = self.d_model
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for spec in self.layer_pattern:
            n = self._layer_params(spec)
            if spec.moe:
                m = spec.moe
                n -= m.num_experts * 3 * d * m.d_ff
                n += (m.top_k + m.num_shared_experts) * 3 * d * m.d_ff
            total += n * self.pattern_repeats
        return total


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(fn):
    """Decorator: register a zero-arg config factory under its module name."""
    name = fn.__module__.rsplit(".", 1)[-1].replace("_", "-")
    _REGISTRY[name] = fn
    return fn


def get_config(name: str) -> ArchConfig:
    # configs register on import; import the package lazily to avoid cycles
    from repro import configs as _pkg  # noqa: F401
    key = name.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]()


def available_archs() -> Sequence[str]:
    from repro import configs as _pkg  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced ("smoke") variants: same family, tiny dims, runnable on CPU.
# ---------------------------------------------------------------------------


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """2 pattern repeats max, d_model<=256, <=4 experts, tiny vocab."""

    def shrink_layer(spec: LayerSpec) -> LayerSpec:
        attn = spec.attention
        if attn is not None:
            heads = min(4, attn.num_heads)
            kv = max(1, min(attn.num_kv_heads, heads))
            while heads % kv:
                kv -= 1
            attn = dataclasses.replace(
                attn,
                num_heads=heads,
                num_kv_heads=kv,
                head_dim=32,
                kv_lora_rank=32 if attn.kv_lora_rank else None,
                q_lora_rank=32 if attn.q_lora_rank else None,
                window=min(attn.window, 64) if attn.window else None,
            )
        ssm = spec.ssm
        if ssm is not None:
            ssm = dataclasses.replace(
                ssm, d_state=16, head_dim=32, chunk_size=32)
        moe = spec.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe,
                num_experts=min(4, moe.num_experts),
                top_k=min(2, moe.top_k),
                d_ff=128,
                num_shared_experts=min(1, moe.num_shared_experts),
                shared_d_ff=128 if moe.num_shared_experts else 0,
            )
        return LayerSpec(
            kind=spec.kind,
            attention=attn,
            ssm=ssm,
            d_ff=256 if spec.d_ff else None,
            moe=moe,
        )

    pattern = tuple(shrink_layer(s) for s in cfg.layer_pattern)
    # keep the pattern (it IS the family) but only repeat once/twice
    repeats = 1 if len(pattern) > 2 else min(2, cfg.pattern_repeats)
    enc = cfg.encoder
    if enc is not None:
        enc = EncoderSpec(num_layers=2, num_heads=4, src_len=64)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=128,
        vocab_size=512,
        layer_pattern=pattern,
        pattern_repeats=repeats,
        encoder=enc,
        stub_frontend_tokens=min(cfg.stub_frontend_tokens, 16),
        max_seq_len=512,
    )
