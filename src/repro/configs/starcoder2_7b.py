"""StarCoder2-7B — dense GQA code model.  [arXiv:2402.19173]

Assigned spec: 32L, d_model=4608, 36 heads (GQA kv=4), d_ff=18432,
vocab=49152.  RoPE.  The released family trains with a 4k sliding window —
we keep full attention as the default and expose window=4096 as the
long-context variant.
"""
from repro.configs.base import ArchConfig, AttentionSpec, LayerSpec, register


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=36, num_kv_heads=4, head_dim=128,
                         rope_theta=1_000_000.0)
    layer = LayerSpec(kind="attn", attention=attn, d_ff=18432, gated_mlp=False)
    return ArchConfig(
        name="starcoder2-7b",
        family="dense",
        d_model=4608,
        vocab_size=49152,
        layer_pattern=(layer,),
        pattern_repeats=32,
        source="arXiv:2402.19173 (StarCoder2)",
        long_context_window=4096,
    )
