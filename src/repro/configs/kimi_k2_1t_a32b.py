"""Kimi K2 — trillion-param MoE (paper-table entry).  [arXiv:2501.kimi2]

Assigned spec: 61L, d_model=7168, 64 heads (GQA kv=8), expert d_ff=2048,
vocab=163840, MoE 384 experts top-8.  We add the family's customary single
shared expert.  head_dim=128 (64×112 would be MXU-unaligned; 128 matches the
released model family convention).
"""
from repro.configs.base import ArchConfig, AttentionSpec, LayerSpec, MoESpec, register


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=64, num_kv_heads=8, head_dim=128,
                         rope_theta=50000.0)
    moe = MoESpec(num_experts=384, top_k=8, d_ff=2048,
                  num_shared_experts=1, shared_d_ff=2048)
    layer = LayerSpec(kind="attn", attention=attn, moe=moe)
    return ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        d_model=7168,
        vocab_size=163840,
        layer_pattern=(layer,),
        pattern_repeats=61,
        source="arXiv:2501.kimi2 (Kimi K2)",
    )
