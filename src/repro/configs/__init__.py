"""Architecture configs (one module per assigned architecture).

Importing this package registers every config in the registry; use
``repro.configs.get_config(name)`` / ``available_archs()``.
"""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    AttentionSpec,
    EncoderSpec,
    LayerSpec,
    MoESpec,
    SSMSpec,
    available_archs,
    get_config,
    reduce_for_smoke,
    register,
)

# Register all architectures (import order = table order in the brief).
from repro.configs import (  # noqa: F401,E402
    kimi_k2_1t_a32b,
    deepseek_v2_lite_16b,
    gemma3_27b,
    starcoder2_7b,
    llava_next_mistral_7b,
    jamba_1_5_large_398b,
    mamba2_1_3b,
    whisper_base,
    mistral_large_123b,
    starcoder2_3b,
)

ASSIGNED_ARCHS = (
    "kimi-k2-1t-a32b",
    "deepseek-v2-lite-16b",
    "gemma3-27b",
    "starcoder2-7b",
    "llava-next-mistral-7b",
    "jamba-1-5-large-398b",
    "mamba2-1-3b",
    "whisper-base",
    "mistral-large-123b",
    "starcoder2-3b",
)
