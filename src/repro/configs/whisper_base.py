"""Whisper-base — encoder-decoder speech model.  [arXiv:2212.04356]

Assigned spec: 6L (x2: 6 encoder + 6 decoder), d_model=512, 8 heads,
d_ff=2048, vocab=51865.  The mel-spectrogram + conv frontend is a STUB per
the brief: ``input_specs()`` provides precomputed frame embeddings
(batch, 1500, 512).  Decoder layers carry cross-attention to the encoder
output.  long_500k decode is architecturally meaningless for this family
(learned positions capped at 448) and is skipped — see
docs/ARCHITECTURE.md §6.
"""
from repro.configs.base import (
    ArchConfig, AttentionSpec, EncoderSpec, LayerSpec, register,
)


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=8, num_kv_heads=8, head_dim=64,
                         rope_theta=10000.0)
    layer = LayerSpec(kind="attn", attention=attn, d_ff=2048, gated_mlp=False)
    return ArchConfig(
        name="whisper-base",
        family="audio",
        d_model=512,
        vocab_size=51865,
        layer_pattern=(layer,),
        pattern_repeats=6,
        encoder=EncoderSpec(num_layers=6, num_heads=8, src_len=1500),
        stub_frontend=True,
        max_seq_len=448,
        source="arXiv:2212.04356 (Whisper)",
    )
