"""LLaVA-NeXT (Mistral-7B backbone) — VLM.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

Assigned spec: 32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=32000.  The vision tower (CLIP/SigLIP + anyres tiling projector) is a
STUB per the brief: ``input_specs()`` provides precomputed patch embeddings
(up to 2880 anyres patch tokens) that the backbone consumes as a sequence
prefix.  Mistral lineage ships sliding-window attention; window=4096 is the
long-context variant.
"""
from repro.configs.base import ArchConfig, AttentionSpec, LayerSpec, register


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=32, num_kv_heads=8, head_dim=128,
                         rope_theta=1_000_000.0)
    layer = LayerSpec(kind="attn", attention=attn, d_ff=14336)
    return ArchConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        d_model=4096,
        vocab_size=32000,
        layer_pattern=(layer,),
        pattern_repeats=32,
        stub_frontend=True,
        stub_frontend_tokens=2880,   # anyres: up to 5 tiles x 576 patches
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        long_context_window=4096,
    )
