"""DeepSeek-V2-Lite 16B — MoE with Multi-head Latent Attention.
[arXiv:2405.04434]

Assigned spec: 27L, d_model=2048, 16 heads, MLA kv_lora_rank=512,
64 routed experts top-6 + 2 shared experts, expert d_ff=1408,
vocab=102400.  (The released model's first layer is a dense FFN; we model
all 27 layers as MoE for a homogeneous scan — noted deviation, <0.5% of
params.)
"""
from repro.configs.base import ArchConfig, AttentionSpec, LayerSpec, MoESpec, register


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=16, num_kv_heads=16, head_dim=128,
                         kv_lora_rank=512, rope_theta=10000.0)
    moe = MoESpec(num_experts=64, top_k=6, d_ff=1408,
                  num_shared_experts=2, shared_d_ff=1408)
    layer = LayerSpec(kind="attn", attention=attn, moe=moe)
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        d_model=2048,
        vocab_size=102400,
        layer_pattern=(layer,),
        pattern_repeats=27,
        source="arXiv:2405.04434 (DeepSeek-V2)",
    )
