"""Mistral-Large-2 123B — dense GQA.
[hf:mistralai/Mistral-Large-Instruct-2407]

Assigned spec: 88L, d_model=12288, 96 heads (GQA kv=8), d_ff=28672,
vocab=32768.  head_dim=128.  Full attention by default; the Mistral lineage
sliding-window (4096) is exposed as the long-context variant.
"""
from repro.configs.base import ArchConfig, AttentionSpec, LayerSpec, register


@register
def config() -> ArchConfig:
    attn = AttentionSpec(num_heads=96, num_kv_heads=8, head_dim=128,
                         rope_theta=1_000_000.0)
    layer = LayerSpec(kind="attn", attention=attn, d_ff=28672)
    return ArchConfig(
        name="mistral-large-123b",
        family="dense",
        d_model=12288,
        vocab_size=32768,
        layer_pattern=(layer,),
        pattern_repeats=88,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
        long_context_window=4096,
    )
