"""Mamba2-1.3B — attention-free SSM with state-space duality (SSD).
[arXiv:2405.21060]

Assigned spec: 48L, d_model=2048, attn-free, d_ff=0 (mixer-only blocks),
vocab=50280, ssm_state=128.  expand=2, head_dim=64 per the released family.
"""
from repro.configs.base import ArchConfig, LayerSpec, SSMSpec, register


@register
def config() -> ArchConfig:
    ssm = SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256)
    layer = LayerSpec(kind="ssm", ssm=ssm)   # no FFN: mixer-only
    return ArchConfig(
        name="mamba2-1-3b",
        family="ssm",
        d_model=2048,
        vocab_size=50280,
        layer_pattern=(layer,),
        pattern_repeats=48,
        tie_embeddings=True,
        max_seq_len=1_048_576,
        source="arXiv:2405.21060 (Mamba-2 / SSD)",
    )
