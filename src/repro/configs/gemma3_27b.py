"""Gemma-3 27B — dense, 5:1 local:global attention interleave, 128k context.
[hf:google/gemma-3-1b-pt family card, scaled per assignment]

Assigned spec: 62L, d_model=5376, 32 heads (GQA kv=16), d_ff=21504,
vocab=262144.  Pattern: 5 sliding-window (1024) layers per 1 global layer.
62 = 31 × 2: we express the pattern as 31 specs (5×[local,]+[global]
repeated 5 times, + 1 trailing local) repeated twice.
"""
from repro.configs.base import ArchConfig, AttentionSpec, LayerSpec, register

_LOCAL = AttentionSpec(num_heads=32, num_kv_heads=16, head_dim=128,
                       window=1024, rope_theta=10000.0)
_GLOBAL = AttentionSpec(num_heads=32, num_kv_heads=16, head_dim=128,
                        rope_theta=1_000_000.0)


@register
def config() -> ArchConfig:
    d_ff = 21504
    local = LayerSpec(kind="attn", attention=_LOCAL, d_ff=d_ff)
    glob = LayerSpec(kind="attn", attention=_GLOBAL, d_ff=d_ff)
    pattern = (([local] * 5 + [glob]) * 5 + [local])
    return ArchConfig(
        name="gemma3-27b",
        family="dense",
        d_model=5376,
        vocab_size=262144,
        layer_pattern=tuple(pattern),
        pattern_repeats=2,
        tie_embeddings=True,
        max_seq_len=131072,
        source="hf:google/gemma-3 family",
        # global layers fall back to split-KV for long_500k; local layers
        # already windowed → long-decode supported via window on globals
        long_context_window=4096,
        long_strategy="mixed",
    )
