"""Buffered-asynchronous FL rounds under client churn.

The paper's round (core/fed.py) is a synchronous barrier: every client
of the cohort trains, uploads, and the server steps once all N payloads
are in.  At the ROADMAP's scale — millions of intermittently-connected
devices — the barrier never closes: clients arrive, straggle, and drop
mid-round.  This module is the buffered-async driver for that traffic
pattern (FedBuff-style; the server-side adaptive step follows the
FedAdamW line of work):

* clients train against **stale parameter snapshots**: a dispatch
  captures ``(W, M, V)`` at server version ``v``; by the time the
  update lands the server may be at version ``v + s``;
* a server-side **buffer** collects ``K`` compressed updates (any
  clients, any staleness); only when the buffer holds exactly ``K``
  does the server apply one aggregate step — never fewer;
* aggregation is **staleness-weighted**: update ``i`` with staleness
  ``s_i`` contributes ``weight_i * (1 + s_i) ** -power``, normalized by
  the buffer's weight total (``staleness_scale`` below; at ``s == 0``
  the scale is exactly 1.0, which is what makes the zero-churn
  degenerate config *bitwise* equal to the sync round);
* updates older than ``max_staleness`` at arrival are **discarded**;
* per-client compressor state (error-feedback residuals, the
  ``local_adam`` persistent moments) is committed **only when the
  update is accepted** into the buffer.  A client that drops after
  compress but before delivery — or whose update is discarded as too
  stale — keeps its state bitwise untouched and retries from it: state
  survives churn, it is never rezeroed (the Efficient-Adam lesson), and
  ``uplink_bits`` counts only updates that actually landed.

Everything runs on a **virtual clock** driven by the deterministic
event model in :mod:`repro.data.churn`: no wall time anywhere, so every
simulation replays bitwise from its seed (the fault-injection harness
in tests/test_async_fed.py leans on this; debugging recipe in
docs/async.md).

The per-client compute and the server arithmetic are the SAME builders
the sync round uses (``fed.make_client_step``, ``fed.make_server_apply``,
``aggregate.ordered_weighted_sum``), composed two ways:

* ``client_exec="scan"``     — simultaneous dispatches run as one
  ``lax.scan`` cohort (the CPU/test path, and the virtual-client path);
* ``client_exec="shardmap"`` — cohorts run under the shard_map MANUAL
  region over ``fed.client_axes``, exactly like ``round_shardmap``
  (requires an ambient mesh; groups are padded to the mesh's client
  count and padded lanes are discarded on the host).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import aggregate, compressors, wire
from repro.core.compressors import DIAG_KEYS, Deltas
from repro.core.fed import (
    FedConfig, FedState, active_client_count, make_client_step,
    make_server_apply,
)
from repro.data.churn import ChurnConfig, ChurnModel

_F32 = jnp.float32


# ---------------------------------------------------------------------------
# Staleness weighting
# ---------------------------------------------------------------------------


def staleness_scale(staleness, power: float = 0.5):
    """Per-update multiplier ``(1 + s) ** -power`` (host math, float64).

    Monotone non-increasing in ``s``, in ``(0, 1]``, and EXACTLY 1.0 at
    ``s == 0`` — so with zero churn the effective weights equal the sync
    round's FedAvg weights bitwise."""
    s = np.asarray(staleness, np.float64)
    assert np.all(s >= 0), "staleness is a count of server steps"
    assert power >= 0.0
    return (1.0 + s) ** (-float(power))


def staleness_weights(staleness, power: float = 0.5) -> np.ndarray:
    """Normalized buffer weights ``w_i = scale(s_i) / sum_j scale(s_j)``.

    Properties (pinned by the hypothesis suite in
    tests/test_async_fed.py): nonnegative, sum to 1, and monotone
    non-increasing in staleness — a staler update never outweighs a
    fresher one.  The driver itself applies the unnormalized
    ``staleness_scale`` times the FedAvg weight and divides by the
    buffer's weight total, which is the same weighting whenever the
    FedAvg weights are uniform."""
    s = staleness_scale(staleness, power)
    return s / s.sum()


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Buffered-async server policy (the churn schedule itself lives in
    :class:`repro.data.churn.ChurnConfig`)."""
    buffer_size: int = 4              # K: updates per server step
    max_staleness: Optional[int] = None   # arrival cutoff; None = accept all
    staleness_power: float = 0.5      # (1+s)**-power aggregation weight

    def __post_init__(self):
        assert self.buffer_size >= 1
        assert self.max_staleness is None or self.max_staleness >= 0
        assert self.staleness_power >= 0.0


# ---------------------------------------------------------------------------
# Traced builders (jit/shard_map roots — guarded by the jit-hazard lint)
# ---------------------------------------------------------------------------


def make_cohort_exec(fed: FedConfig, loss_fn: Callable, has_cs: bool,
                     comp: Optional[compressors.Compressor] = None):
    """Run a group of simultaneously-dispatched clients as ONE
    ``lax.scan`` over ``fed.make_client_step`` — the same body shape as
    ``round_scan``, so per-client outputs are bitwise those of the sync
    driver.  ``exec_cohort(W, M, V, batches, cstates) -> (sW, sM, sV,
    new_cs, mets)`` with every output stacked ``(G, ...)``."""
    client_step = make_client_step(fed, loss_fn, comp)

    def exec_cohort(W, M, V, batches, cstates):
        def body(carry, xs):
            if has_cs:
                batch, cstate = xs
            else:
                batch, cstate = xs, None
            sW, sM, sV, ncs, mets = client_step(W, M, V, batch, cstate)
            return carry, (sW, sM, sV, ncs if has_cs else 0.0, mets)

        xs = (batches, cstates) if has_cs else batches
        _, (sW, sM, sV, ncs, mets) = lax.scan(body, 0.0, xs)
        return sW, sM, sV, (ncs if has_cs else None), mets

    return jax.jit(exec_cohort)


def make_mesh_cohort_exec(fed: FedConfig, loss_fn: Callable, has_cs: bool,
                          comp: Optional[compressors.Compressor] = None,
                          mesh=None):
    """shard_map realization of the cohort exec: one spatial client per
    device row over ``fed.client_axes``, exactly the MANUAL region of
    ``fed.round_shardmap``.  ``mesh`` may be omitted if an ambient mesh
    is active via ``repro.compat.set_mesh``.  The group's leading axis G
    must equal the client-axes device count — the host pads smaller
    groups."""
    from repro.compat import shard_map

    client_step = make_client_step(fed, loss_fn, comp)
    caxes = tuple(fed.client_axes)
    cax = caxes if len(caxes) > 1 else caxes[0]

    def exec_cohort(W, M, V, batches, cstates):
        def body(Wb, Mb, Vb, batch, cstate):
            batch_l = jax.tree.map(lambda x: x[0], batch)
            cstate_l = jax.tree.map(lambda x: x[0], cstate)
            sW, sM, sV, ncs, mets = client_step(Wb, Mb, Vb, batch_l,
                                                cstate_l)
            lead = lambda t: jax.tree.map(lambda x: x[None], t)
            return (lead(sW), lead(sM), lead(sV), lead(ncs),
                    jax.tree.map(lambda x: x[None], mets))

        rep = lambda tree: jax.tree.map(lambda _: PartitionSpec(), tree)
        stk = lambda tree: jax.tree.map(
            lambda x: PartitionSpec(cax, *([None] * (x.ndim - 1))), tree)
        mets_spec = {k: PartitionSpec(cax)
                     for k in list(DIAG_KEYS) + ["loss"]}
        sW, sM, sV, ncs, mets = shard_map(
            body, mesh,
            in_specs=(rep(W), rep(M), rep(V), stk(batches), stk(cstates)),
            out_specs=(stk(W), stk(W), stk(W), stk(cstates), mets_spec),
            axis_names=frozenset(caxes),
            check_vma=False,
        )(W, M, V, batches, cstates)
        return sW, sM, sV, (ncs if has_cs else None), mets

    return exec_cohort


def make_buffer_apply(fed: FedConfig,
                      comp: Optional[compressors.Compressor] = None):
    """One server step from a full buffer: ``apply(W, M, V, bufW, bufM,
    bufV, weights) -> (W', M', V')``.  ``buf*`` leaves are stacked
    ``(K, ...)``; ``weights`` is the (K,) effective weight vector
    (FedAvg weight x staleness scale).  Accumulation replays the scan
    driver's exact order and arithmetic (``aggregate.
    ordered_weighted_sum`` + the shared ``fed.make_server_apply``
    tail), so the K = cohort, zero-staleness case is bit-identical to
    ``round_scan``."""
    server_apply = make_server_apply(fed, comp)

    def wsum_fold(carry, w):
        return carry + w, 0.0

    def buffer_apply(W, M, V, bufW, bufM, bufV, weights):
        aW = aggregate.ordered_weighted_sum(bufW, weights)
        aM = aggregate.ordered_weighted_sum(bufM, weights)
        aV = aggregate.ordered_weighted_sum(bufV, weights)
        # left-fold, like round_scan's running wsum (not jnp.sum, whose
        # reduction order XLA may reassociate)
        wsum, _ = lax.scan(wsum_fold, jnp.zeros((), _F32), weights)
        return server_apply(W, M, V, aW, aM, aV, wsum)

    return jax.jit(buffer_apply)


def make_wire_buffer_apply(fed: FedConfig,
                           comp: Optional[compressors.Compressor] = None):
    """Wire-format twin of :func:`make_buffer_apply`: the buffer holds
    the K landed :class:`~repro.core.wire.WirePayload`\\ s (stacked
    ``(K, ...)``) — the bytes that actually crossed the uplink — and the
    server decodes them against the params template and folds in arrival
    order (``aggregate.wire_gather_sum``, which replays ``round_scan``'s
    exact arithmetic), so the degenerate-config bitwise equivalence is
    preserved payload-for-payload."""
    if comp is None:
        comp = compressors.make_compressor(fed)
    server_apply = make_server_apply(fed, comp)

    def wsum_fold(carry, w):
        return carry + w, 0.0

    def buffer_apply(W, M, V, payloads, weights):
        aW, aM, aV = aggregate.wire_gather_sum(comp, payloads, W, weights)
        wsum, _ = lax.scan(wsum_fold, jnp.zeros((), _F32), weights)
        return server_apply(W, M, V, aW, aM, aV, wsum)

    return jax.jit(buffer_apply)


def make_commit_client(has_cs: bool):
    """``commit(cs, new_c, c) -> cs`` — write ONE accepted client's new
    compressor state into slot ``c`` of the stacked ``client_state``
    (the only mutation path: drops and discards never reach it)."""

    def commit(cs, new_c, c):
        if not has_cs:
            return None
        return jax.tree.map(lambda full, new: full.at[c].set(new),
                            cs, new_c)

    return jax.jit(commit, static_argnums=())


# ---------------------------------------------------------------------------
# The driver
# ---------------------------------------------------------------------------

_EV_DISPATCH, _EV_ARRIVE = 0, 1


class AsyncRoundDriver:
    """Event-driven buffered-async simulation (see module docstring).

    Host-side orchestration over a virtual clock; all numerics run in
    the jitted builders above.  Build via :func:`make_async_round`."""

    def __init__(self, fed: FedConfig, loss_fn: Callable,
                 acfg: AsyncConfig, churn: Optional[ChurnModel] = None,
                 client_exec: str = "scan", mesh=None):
        assert client_exec in ("scan", "shardmap"), client_exec
        if client_exec == "shardmap":
            assert fed.client_axes, "shardmap exec needs fed.client_axes"
            assert mesh is not None, "shardmap exec needs a concrete mesh"
        self.mesh = mesh
        self.fed = fed
        self.acfg = acfg
        self.churn = churn if churn is not None \
            else ChurnModel(ChurnConfig(), fed.n_clients)
        assert self.churn.n_clients == fed.n_clients
        self.client_exec = client_exec
        self._loss_fn = loss_fn
        self._comp = compressors.make_compressor(fed)
        self._apply = make_buffer_apply(fed, self._comp)
        self._exec = None          # built on first run (has_cs known then)
        self._commit = None
        self._apply_wire = None    # wire-format server step (lazy)
        self._repack = None        # carriers -> WirePayload (lazy)

    # -- helpers --------------------------------------------------------

    def _build(self, has_cs: bool):
        if self._exec is not None:
            return
        if self.client_exec == "shardmap":
            self._exec = make_mesh_cohort_exec(
                self.fed, self._loss_fn, has_cs, self._comp, self.mesh)
        else:
            self._exec = make_cohort_exec(
                self.fed, self._loss_fn, has_cs, self._comp)
        self._commit = make_commit_client(has_cs)

    def _run_group(self, W, M, V, batches, cs, group, has_cs):
        """Execute clients ``group`` (all dispatched at the same tick)
        against the snapshot (W, M, V); returns per-client payload
        dicts indexed like ``group``."""
        idx = list(group)
        if self.client_exec == "shardmap":
            # fixed cohort width = client-axes device count; pad by
            # repeating the last client, discard the padded lanes below
            pad_to = int(np.prod(
                [self.mesh.shape[a] for a in self.fed.client_axes]))
            assert len(idx) <= pad_to, (len(idx), pad_to)
            idx = idx + [idx[-1]] * (pad_to - len(idx))
        sel = np.asarray(idx, np.int64)
        take = lambda t: jax.tree.map(lambda x: x[sel], t)
        g_batches = take(batches)
        g_cs = take(cs) if has_cs else None
        sW, sM, sV, ncs, mets = self._exec(W, M, V, g_batches, g_cs)
        out = []
        for i, _c in enumerate(group):
            pick = lambda t: jax.tree.map(lambda x: x[i], t)
            out.append(dict(
                sW=pick(sW), sM=pick(sM), sV=pick(sV),
                ncs=(pick(ncs) if has_cs else None),
                loss=mets["loss"][i]))
        return out

    # -- the simulation -------------------------------------------------

    def __call__(self, state: FedState, batches, weights=None, *,
                 rounds: int = 1, max_events: Optional[int] = None):
        """Run until ``rounds`` server steps have been applied (or the
        ``max_events`` budget runs out — e.g. churn so hostile the
        buffer never fills; then ``metrics["server_steps"] < rounds``
        and the returned state reflects only the steps that happened).

        ``batches``: client-major pytree, leaves ``(C, ...)`` — client
        ``c`` trains on slice ``c`` at every dispatch.  ``weights``:
        optional (C,) FedAvg weights.  Returns ``(FedState, metrics)``;
        ``metrics["events"]`` is the full replayable event log."""
        fed, acfg = self.fed, self.acfg
        C = fed.n_clients
        K = acfg.buffer_size
        if weights is None:
            weights = np.ones((C,), np.float64)
        base_w = np.asarray(weights, np.float64)
        assert base_w.shape == (C,)
        if max_events is None:
            max_events = 64 * C * max(1, rounds) + 256

        has_cs = state.client_state is not None
        self._build(has_cs)
        W, M, V = state.W, state.M, state.V
        cs = state.client_state
        server_round = int(state.round)
        round0 = server_round

        d = sum(x.size for x in jax.tree.leaves(W))
        sizes = tuple(x.size for x in jax.tree.leaves(W))
        # wire mode: buffer the bit-packed WirePayloads and bill the
        # MEASURED landed bytes; analytic fallback only for configs with
        # no wire realization (q_bits != 32 etc.)
        wire_mode = self._comp.wire_bits_per_client(sizes) is not None
        bits_client = self._comp.bits_per_client(d)
        if wire_mode and self._repack is None:
            comp = self._comp
            self._repack = jax.jit(
                lambda sW, sM, sV: comp.pack_wire(Deltas(sW, sM, sV)))
            self._apply_wire = make_wire_buffer_apply(fed, comp)

        # participation: the async realization of the seam documented on
        # fed.active_client_count — the dispatch pool is exactly the
        # n_active sampled clients; everyone else never dispatches
        if fed.participation < 1.0:
            pool = self.churn.participation_pool(active_client_count(fed))
        else:
            pool = np.arange(C)

        q: List = []
        seq = itertools.count()
        push = lambda t, kind, payload: heapq.heappush(
            q, (t, next(seq), kind, payload))
        for c in pool:
            push(0, _EV_DISPATCH, int(c))

        attempts = {int(c): 0 for c in pool}
        inflight: Dict[int, Dict[str, Any]] = {}
        buffer: List[Dict[str, Any]] = []
        events: List[tuple] = []
        landed = dropped = discarded = steps = 0
        bits_total = 0
        bits_per_step: List[int] = []
        loss_per_step: List[float] = []

        def redispatch(t, c):
            push(t + self.churn.cfg.rejoin_delay, _EV_DISPATCH, c)

        n_events = 0
        while q and steps < rounds and n_events < max_events:
            t, _, kind, c = heapq.heappop(q)
            n_events += 1

            if kind == _EV_DISPATCH:
                # group every dispatch sharing this tick (consecutive in
                # the queue — no ARRIVE can interleave at lower seq) into
                # one cohort against one snapshot
                group = [c]
                while q and q[0][0] == t and q[0][2] == _EV_DISPATCH:
                    group.append(heapq.heappop(q)[3])
                    n_events += 1
                payloads = self._run_group(W, M, V, batches, cs, group,
                                           has_cs)
                for gc, pay in zip(group, payloads):
                    a = attempts[gc]
                    attempts[gc] += 1
                    fate = self.churn.fate(gc, a)
                    pay["ver"] = server_round
                    pay["drop"] = fate.drop
                    inflight[gc] = pay
                    events.append((t, "dispatch", gc, a))
                    push(t + fate.duration, _EV_ARRIVE, gc)
                continue

            # _EV_ARRIVE: delivery attempt for client c
            rec = inflight.pop(c)
            stale = server_round - rec["ver"]
            if rec["drop"]:
                # lost after compress, before delivery: nothing lands,
                # nothing is committed, nothing is billed
                dropped += 1
                events.append((t, "drop", c, stale))
            elif acfg.max_staleness is not None \
                    and stale > acfg.max_staleness:
                # too stale at arrival: same guarantees as a drop
                discarded += 1
                events.append((t, "discard", c, stale))
            else:
                # ACCEPT: the only path that commits client state and
                # bills uplink bits
                if has_cs:
                    cs = self._commit(cs, rec["ncs"], c)
                landed += 1
                if wire_mode:
                    # re-materialize the landed bytes (pack_wire is
                    # idempotent on the decoded carriers) and bill the
                    # MEASURED payload size — drops/discards above never
                    # reach this line, so they stay unbilled
                    rec["wire"] = self._repack(rec["sW"], rec["sM"],
                                               rec["sV"])
                    bits_total += 8 * wire.payload_nbytes(rec["wire"])
                else:
                    bits_total += bits_client
                eff_w = float(base_w[c]) \
                    * float(staleness_scale(stale, acfg.staleness_power))
                buffer.append(dict(rec, stale=stale, w=eff_w))
                events.append((t, "deliver", c, stale))
                if len(buffer) == K:
                    stack = lambda key: jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[e[key] for e in buffer])
                    wts = jnp.asarray([e["w"] for e in buffer], _F32)
                    if wire_mode:
                        # the buffer holds WirePayloads: the server step
                        # decodes the transported bytes themselves
                        W, M, V = self._apply_wire(W, M, V, stack("wire"),
                                                   wts)
                    else:
                        W, M, V = self._apply(W, M, V, stack("sW"),
                                              stack("sM"), stack("sV"), wts)
                    server_round += 1
                    steps += 1
                    bits_per_step.append(bits_total - sum(bits_per_step))
                    loss_per_step.append(float(np.mean(
                        [float(e["loss"]) for e in buffer])))
                    events.append((t, "server_step", steps,
                                   [e["stale"] for e in buffer]))
                    buffer = []
            redispatch(t, c)

        new_state = FedState(
            W=W, M=M, V=V,
            round=jnp.asarray(round0 + steps, jnp.int32),
            client_state=cs)
        metrics = {
            "uplink_bits": jnp.asarray(bits_total, _F32),
            "bits_per_step": bits_per_step,
            "loss_per_step": loss_per_step,
            "server_steps": steps,
            "landed": landed,
            "dropped": dropped,
            "discarded": discarded,
            "buffer_pending": len(buffer),
            "events": events,
        }
        return new_state, metrics


def make_async_round(fed: FedConfig, loss_fn: Callable,
                     acfg: Optional[AsyncConfig] = None, *,
                     churn: Optional[ChurnModel] = None,
                     client_exec: str = "scan",
                     mesh=None) -> AsyncRoundDriver:
    """Build the buffered-async driver (mirrors ``make_fl_round``).

    ``run(state, batches, weights=None, rounds=1) -> (state, metrics)``
    where ``state`` is the same :class:`FedState` the sync round uses —
    the two drivers are interchangeable on a checkpoint."""
    return AsyncRoundDriver(fed, loss_fn, acfg or AsyncConfig(),
                            churn=churn, client_exec=client_exec,
                            mesh=mesh)
