"""The paper's primary contribution: FedAdam-SSM — sparse, mask-aligned
federated Adam (sparsifiers, shared-mask rules, the FL round, baselines,
communication accounting, and the Theorem-1/2/3 bound calculators)."""
from repro.core.fed import (  # noqa: F401
    ALGORITHMS,
    FedConfig,
    FedState,
    fed_init,
    make_fl_round,
)
from repro.core import comm, compressors, masks, quantize, sparsify  # noqa: F401
from repro.core.compressors import (  # noqa: F401
    Compressor,
    Deltas,
    Packed,
    make_compressor,
)
