"""The paper's primary contribution: FedAdam-SSM — sparse, mask-aligned
federated Adam (sparsifiers, shared-mask rules, the FL round, baselines,
communication accounting, and the Theorem-1/2/3 bound calculators)."""
from repro.core.fed import (  # noqa: F401
    ALGORITHMS,
    FedConfig,
    FedState,
    active_client_count,
    fed_init,
    make_client_step,
    make_fl_round,
    make_server_apply,
)
from repro.core.async_fed import (  # noqa: F401
    AsyncConfig,
    make_async_round,
    staleness_scale,
    staleness_weights,
)
from repro.core import comm, compressors, masks, quantize, sparsify  # noqa: F401
from repro.core.compressors import (  # noqa: F401
    Compressor,
    Deltas,
    Packed,
    make_compressor,
)
