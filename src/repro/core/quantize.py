"""Quantizers for the quantized-FedAdam baselines (1-bit Adam,
Efficient-Adam) and for the beyond-paper low-precision transports.

All quantizers are blockwise (one fp32 scale per `block` elements) and come
with an exact dequantizer, so error-feedback residuals are computable.

These are the primitives under the stateful EF compressors in
core/compressors/quantized.py (see docs/compressors.md).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def _blocks(x: jax.Array, block: int):
    flat = x.reshape(-1).astype(_F32)
    n = flat.size
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), n, pad


def sign_quant(x: jax.Array, block: int = 1024) -> jax.Array:
    """1-bit sign quantization with per-block L1 scale (1-bit Adam).

    Strictly two-valued per block (``+scale`` for x >= 0, ``-scale``
    otherwise) so the output is exactly representable as a sign bitplane
    plus one f32 scale per block — the 1-bit Adam wire format
    (core/wire.py)."""
    xb, n, _ = _blocks(x, block)
    scale = jnp.mean(jnp.abs(xb), axis=1, keepdims=True)
    q = jnp.where(xb >= 0, scale, -scale)
    return q.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def uniform_encode(x: jax.Array, bits: int = 8,
                   block: int = 1024) -> Tuple[jax.Array, jax.Array]:
    """Encoder half of :func:`uniform_quant`: symmetric b-bit codes plus
    per-block max scales.  Returns ``(codes int32 of x.shape, scales
    (nb,) f32)`` with codes in ``[-qmax, qmax]``."""
    xb, n, _ = _blocks(x, block)
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / qmax + 1e-30
    q = jnp.round(xb / scale)
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int32)
    codes = q.reshape(-1)[:n].reshape(x.shape)
    return codes, scale.reshape(-1).astype(_F32)


def uniform_decode(codes: jax.Array, scales: jax.Array,
                   block: int = 1024) -> jax.Array:
    """Exact dequantizer for :func:`uniform_encode` (f32 result)."""
    cb, n, _ = _blocks(codes, block)
    q = cb * scales[:, None]
    return q.reshape(-1)[:n].reshape(codes.shape)


def uniform_quant(x: jax.Array, bits: int = 8, block: int = 1024) -> jax.Array:
    """Symmetric b-bit uniform quantization with per-block max scale
    (``uniform_decode(*uniform_encode(x))`` — the wire round trip)."""
    q = uniform_decode(*uniform_encode(x, bits, block), block=block)
    return q.astype(x.dtype)


def int8_store(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Beyond-paper: int8 + per-block scale storage for resident global
    moments (memory-roofline optimization).  Returns (q_int8, scales)."""
    xb, n, pad = _blocks(x, block)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0 + 1e-30
    q = jnp.round(xb / scale[:, None]).astype(jnp.int8)
    return q, scale.astype(_F32)


def int8_load(q: jax.Array, scale: jax.Array, shape, dtype,
              block: int = 256) -> jax.Array:
    flat = (q.astype(_F32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def tree_sign_quant(tree, block: int = 1024):
    return jax.tree.map(lambda x: sign_quant(x, block), tree)


def tree_uniform_quant(tree, bits: int = 8, block: int = 1024):
    return jax.tree.map(lambda x: uniform_quant(x, bits, block), tree)
