"""Quantizing compressors with error feedback — the 1-bit Adam and
Efficient-Adam baselines (Section IV / VII).

Both are only correct as *stateful* operators: the quantization residual
``d - Q(d)`` must be added back into the next round's input, otherwise
the bias accumulates and the methods diverge.  ``init_state`` therefore
always allocates the per-client residual tree; :mod:`repro.core.fed`
carries it through the ``scan``/``vmap`` client axes.

* ``OneBitAdamCompressor``  — sign-quantizes the *momentum* delta with a
  per-block L1 scale (``local_update="momentum"``: one momentum step per
  round, V frozen after warmup; ``server_update="precond_m"`` applies the
  frozen-V preconditioned step).  Bits: ``N (d + q ceil(d/B))``.
* ``EfficientAdamCompressor`` — b-bit uniform-quantizes the *weight*
  delta; local Adam moments are persistent and never aggregated (the
  staleness the paper criticizes; ``local_update="local_adam"``).
  Bits: ``N (b d + q ceil(d/B))``.

See ``docs/compressors.md``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import comm, quantize, wire
from repro.core.compressors.base import (
    Compressor, Deltas, Packed, diag_metrics, register, tree_add,
    tree_size, tree_sub, tree_zeros_like,
)


@dataclasses.dataclass(frozen=True)
class OneBitAdamCompressor(Compressor):
    """1-bit Adam: EF sign quantization of the momentum delta."""

    name: str = "onebit_adam"
    block: int = 1024
    q_bits: int = 32

    transport = "quantized"
    local_update = "momentum"
    server_update = "precond_m"
    wire_layout = "sign"

    def init_state(self, params):
        return {"err": jax.tree.map(jnp.zeros_like, params)}

    def _wire_ok(self) -> bool:
        # the wire's scale stream is one f32 per SCALE_BLOCK elements —
        # only that block size (and q = 32) matches the layout constants
        return self.block == wire.SCALE_BLOCK \
            and self.q_bits == wire.VALUE_BITS

    def compress(self, deltas: Deltas, state):
        assert state is not None, "1-bit Adam requires error-feedback state"
        dM = tree_add(deltas.M, state["err"])
        q = quantize.tree_sign_quant(dM, self.block)
        new_state = {"err": tree_sub(dM, q)}
        z = tree_zeros_like(q)
        ef = Deltas(deltas.W, dM, deltas.V)
        payload = wire.pack_sign(q) if self._wire_ok() else None
        packed = Packed(z, q, tree_zeros_like(deltas.V),
                        diag_metrics(ef, Deltas(deltas.W, q, deltas.V)),
                        payload)
        return packed, new_state, self.bits_per_client(tree_size(deltas.W))

    def pack_wire(self, carriers: Deltas):
        # the M carrier is two-valued +-scale per block, so re-encoding
        # a decoded carrier recovers the same scales/signs bitwise
        if not self._wire_ok():
            return None
        return wire.pack_sign(carriers.M)

    def unpack_wire(self, payload, like) -> Deltas:
        z = tree_zeros_like(like)
        return Deltas(z, wire.unpack_sign(payload, like),
                      tree_zeros_like(like))

    def bits_per_client(self, d: int) -> int:
        return comm.bits_onebit_adam(d, 1, self.q_bits, block=self.block)

    def wire_bits_per_client(self, sizes):
        if not self._wire_ok():
            return None
        return wire.sign_wire_bits(sizes)


@dataclasses.dataclass(frozen=True)
class EfficientAdamCompressor(Compressor):
    """Efficient-Adam: EF b-bit uniform quantization of the weight delta."""

    name: str = "efficient_adam"
    quant_bits: int = 8
    block: int = 1024
    q_bits: int = 32

    transport = "quantized"
    local_update = "local_adam"
    server_update = "w_only"
    wire_layout = "bbit"

    def init_state(self, params):
        return {"err": jax.tree.map(jnp.zeros_like, params)}

    def _wire_ok(self) -> bool:
        return self.block == wire.SCALE_BLOCK \
            and self.q_bits == wire.VALUE_BITS \
            and self.quant_bits in (2, 4, 8)

    def compress(self, deltas: Deltas, state):
        assert state is not None, \
            "Efficient-Adam requires error-feedback state"
        dW = tree_add(deltas.W, state["err"])
        # split quantization into encode (codes + scales: the wire
        # arrays) and decode (the dense carrier) — the composition is
        # bitwise ``quantize.tree_uniform_quant``
        leaves, treedef = jax.tree_util.tree_flatten(dW)
        enc = [quantize.uniform_encode(x, self.quant_bits, self.block)
               for x in leaves]
        q = jax.tree_util.tree_unflatten(treedef, [
            quantize.uniform_decode(c, s, self.block).astype(x.dtype)
            for (c, s), x in zip(enc, leaves)])
        new_state = {"err": tree_sub(dW, q)}
        ef = Deltas(dW, deltas.M, deltas.V)
        payload = wire.pack_bbit_codes(
            [c for c, _ in enc], [s for _, s in enc], self.quant_bits) \
            if self._wire_ok() else None
        packed = Packed(q, tree_zeros_like(deltas.M),
                        tree_zeros_like(deltas.V),
                        diag_metrics(ef, Deltas(q, deltas.M, deltas.V)),
                        payload)
        return packed, new_state, self.bits_per_client(tree_size(deltas.W))

    def pack_wire(self, carriers: Deltas):
        if not self._wire_ok():
            return None
        leaves, _ = jax.tree_util.tree_flatten(carriers.W)
        enc = [quantize.uniform_encode(x, self.quant_bits, self.block)
               for x in leaves]
        return wire.pack_bbit_codes(
            [c for c, _ in enc], [s for _, s in enc], self.quant_bits)

    def unpack_wire(self, payload, like) -> Deltas:
        w = wire.unpack_bbit_codes(payload, like, self.quant_bits)
        return Deltas(w, tree_zeros_like(like), tree_zeros_like(like))

    def bits_per_client(self, d: int) -> int:
        return comm.bits_efficient_adam(d, 1, self.q_bits,
                                        bits=self.quant_bits,
                                        block=self.block)

    def wire_bits_per_client(self, sizes):
        if not self._wire_ok():
            return None
        return wire.bbit_wire_bits(sizes, self.quant_bits)


@register("onebit_adam")
def _onebit(fed) -> OneBitAdamCompressor:
    return OneBitAdamCompressor(q_bits=fed.q_bits)


@register("efficient_adam")
def _efficient(fed) -> EfficientAdamCompressor:
    return EfficientAdamCompressor(quant_bits=fed.quant_bits,
                                   q_bits=fed.q_bits)
