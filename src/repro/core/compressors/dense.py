"""Identity (dense) compressors — the FedAdam / FedSGD baselines.

Nothing is dropped: the full f32/bf16 triple crosses the uplink, so the
bit cost is ``n_tensors * d * q`` per client (Section IV's 3Ndq for
FedAdam, Ndq for FedSGD).  These exist so the dense baselines ride the
same registry/round machinery as every sparse and quantized scheme.

See ``docs/compressors.md``.
"""
from __future__ import annotations

import dataclasses

from repro.core import comm, wire
from repro.core.compressors.base import (
    Compressor, Deltas, Packed, diag_metrics, register, tree_size,
    tree_zeros_like,
)


@dataclasses.dataclass(frozen=True)
class DenseCompressor(Compressor):
    """Identity operator over ``n_tensors`` communicated tensors."""

    name: str = "fedadam"
    q_bits: int = 32
    n_tensors: int = 3                 # W, M, V (FedAdam) vs W only (FedSGD)
    local_update: str = "adam"
    server_update: str = "wmv"

    transport = "dense"
    wire_layout = "dense"

    def _wire_ok(self) -> bool:
        # the wire ships f32 planes — exact only at the paper's q = 32
        return self.q_bits == wire.VALUE_BITS

    def compress(self, deltas: Deltas, state):
        packed = Packed(deltas.W, deltas.M, deltas.V,
                        diag_metrics(deltas, deltas),
                        self.pack_wire(deltas))
        return packed, state, self.bits_per_client(tree_size(deltas.W))

    def pack_wire(self, carriers: Deltas):
        if not self._wire_ok():
            return None
        trees = (carriers.W, carriers.M, carriers.V)[:self.n_tensors]
        return wire.pack_dense(trees)

    def unpack_wire(self, payload, like) -> Deltas:
        planes = wire.unpack_dense(payload, like)
        zeros = tree_zeros_like(like)
        if self.n_tensors == 3:
            return Deltas(*planes)
        return Deltas(planes[0], zeros, zeros)

    def bits_per_client(self, d: int) -> int:
        if self.n_tensors == 3:
            return comm.bits_fedadam(d, 1, self.q_bits)
        return comm.bits_fedsgd(d, 1, self.q_bits)

    def wire_bits_per_client(self, sizes):
        if not self._wire_ok():
            return None
        return wire.dense_wire_bits(sizes, self.n_tensors)


@register("fedadam")
def _fedadam(fed) -> DenseCompressor:
    return DenseCompressor(name="fedadam", q_bits=fed.q_bits, n_tensors=3)


@register("fedsgd")
def _fedsgd(fed) -> DenseCompressor:
    return DenseCompressor(name="fedsgd", q_bits=fed.q_bits, n_tensors=1,
                           local_update="sgd", server_update="w_only")
