"""Identity (dense) compressors — the FedAdam / FedSGD baselines.

Nothing is dropped: the full f32/bf16 triple crosses the uplink, so the
bit cost is ``n_tensors * d * q`` per client (Section IV's 3Ndq for
FedAdam, Ndq for FedSGD).  These exist so the dense baselines ride the
same registry/round machinery as every sparse and quantized scheme.

See ``docs/compressors.md``.
"""
from __future__ import annotations

import dataclasses

from repro.core import comm
from repro.core.compressors.base import (
    Compressor, Deltas, Packed, diag_metrics, register, tree_size,
)


@dataclasses.dataclass(frozen=True)
class DenseCompressor(Compressor):
    """Identity operator over ``n_tensors`` communicated tensors."""

    name: str = "fedadam"
    q_bits: int = 32
    n_tensors: int = 3                 # W, M, V (FedAdam) vs W only (FedSGD)
    local_update: str = "adam"
    server_update: str = "wmv"

    transport = "dense"

    def compress(self, deltas: Deltas, state):
        packed = Packed(deltas.W, deltas.M, deltas.V,
                        diag_metrics(deltas, deltas))
        return packed, state, self.bits_per_client(tree_size(deltas.W))

    def bits_per_client(self, d: int) -> int:
        if self.n_tensors == 3:
            return comm.bits_fedadam(d, 1, self.q_bits)
        return comm.bits_fedsgd(d, 1, self.q_bits)


@register("fedadam")
def _fedadam(fed) -> DenseCompressor:
    return DenseCompressor(name="fedadam", q_bits=fed.q_bits, n_tensors=3)


@register("fedsgd")
def _fedsgd(fed) -> DenseCompressor:
    return DenseCompressor(name="fedsgd", q_bits=fed.q_bits, n_tensors=1,
                           local_update="sgd", server_update="w_only")
