"""The ``Compressor`` protocol and registry — Section IV as an API.

Every uplink scheme in the paper (and every baseline it compares against)
is a *compression operator* applied to the client's local update triple
``(dW, dM, dV)`` before it crosses the network.  Efficient-Adam and 1-bit
Adam are only correct when the operator is *stateful*: the part of the
update the compressor dropped this round (the error-feedback residual)
must be added back into the next round's input.  This module makes that
shape first-class:

* ``Deltas``   — the raw local update triple (pytrees of dW, dM, dV).
* ``Packed``   — a compressed triple plus encoder-side diagnostics.  The
  carrier stays *dense* (masked / quantized values in place); the wire
  realization (COO pack + all-gather) is a transport concern handled by
  :func:`repro.core.aggregate.packed_gather_sum` keyed on the
  compressor's ``transport`` tag.
* ``Compressor`` — ``init_state(params) -> state``,
  ``compress(deltas, state) -> (packed, state, bits)``,
  ``decompress(packed) -> deltas``.  ``state`` is per-client and is
  carried through the ``scan``/``vmap`` client axes by
  :mod:`repro.core.fed`; ``bits`` is the exact per-client uplink cost of
  the payload (the Section IV/VII formulas of :mod:`repro.core.comm`),
  so the reported metric can never drift from the transport used.
  Because ``state`` is the SOLE carrier of cross-round client memory,
  the buffered-async driver (:mod:`repro.core.async_fed`) can give it
  commit-on-accept semantics: a client whose update is lost or
  discarded mid-flight keeps its residual bitwise intact and simply
  retries from it — state is never rezeroed by churn (docs/async.md).

Declarative dispatch tags (read by ``core/fed.py`` so that adding a
compressor never requires editing the round):

* ``transport``     — ``dense`` | ``shared_sparse`` |
  ``independent_sparse`` | ``quantized``; selects the aggregation
  transport in ``core/aggregate.py``.
* ``local_update``  — ``adam`` | ``sgd`` | ``momentum`` | ``local_adam``;
  which client-side optimizer produces the deltas this compressor eats.
* ``server_update`` — ``wmv`` (advance W, M and V by the aggregate) |
  ``w_only`` | ``precond_m`` (1-bit Adam's frozen-V preconditioned step).

Registering a new scheme is a single-file drop-in::

    from repro.core.compressors import Compressor, Packed, register

    @register("fedlion_sign")
    def _factory(fed):
        return SignCompressor(q_bits=fed.q_bits)

See ``docs/compressors.md`` for the full contract and the per-algorithm
bit formulas.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sparsify as S

_F32 = jnp.float32

#: Canonical diagnostic keys every compressor reports (fed.py's scan/vmap
#: drivers stack these per client; shard_map needs the key set static).
DIAG_KEYS = ("err_w", "err_m", "err_v", "norm_dw", "norm_dm", "norm_dv")


class Deltas(NamedTuple):
    """The client's raw local update: pytrees of dW, dM, dV (Algorithm 2
    step 3).  Slots an algorithm does not communicate hold zeros-like
    trees (e.g. FedSGD only fills ``W``)."""
    W: Any
    M: Any
    V: Any


class Packed(NamedTuple):
    """A compressed update triple.

    ``W``/``M``/``V`` are the dense carriers of the compressed values
    (masked or quantized in place).  ``diag`` holds encoder-side
    diagnostics (:data:`DIAG_KEYS`) — computed where the error-feedback
    adjusted input exists, and explicitly NOT part of the transported
    payload (it never enters the bit accounting).  ``wire`` is the
    bit-packed :class:`repro.core.wire.WirePayload` realization of the
    carriers — the arrays that actually cross the uplink (``None`` only
    for configurations outside the wire format's layout constants, which
    fall back to dense transport + analytic accounting)."""
    W: Any
    M: Any
    V: Any
    diag: Dict[str, jax.Array]
    wire: Any = None


def tree_sub(a, b):
    """Elementwise a - b in f32, cast back to the leaf dtype."""
    return jax.tree.map(lambda x, y: (x.astype(_F32) - y.astype(_F32))
                        .astype(x.dtype), a, b)


def tree_add(a, b):
    return jax.tree.map(lambda x, y: (x.astype(_F32) + y.astype(_F32))
                        .astype(x.dtype), a, b)


def tree_zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


def tree_size(t) -> int:
    return sum(x.size for x in jax.tree.leaves(t))


def zero_diag() -> Dict[str, jax.Array]:
    z = jnp.zeros((), _F32)
    return {k: z for k in DIAG_KEYS}


def diag_metrics(deltas: Deltas, recon: Deltas) -> Dict[str, jax.Array]:
    """Default diagnostics: per-tensor compression error ||d - C(d)||_2
    (the Theorem-1 divergence terms) and input norms.  ``deltas`` should
    be the error-feedback adjusted encoder input when EF is active."""
    nd = lambda d, r: S.tree_norm(tree_sub(d, r))
    return {
        "err_w": nd(deltas.W, recon.W),
        "err_m": nd(deltas.M, recon.M),
        "err_v": nd(deltas.V, recon.V),
        "norm_dw": S.tree_norm(deltas.W),
        "norm_dm": S.tree_norm(deltas.M),
        "norm_dv": S.tree_norm(deltas.V),
    }


class Compressor:
    """Base class / protocol.  Subclasses override :meth:`compress` and
    :meth:`bits_per_client`, plus any of the dispatch tags below."""

    name: str = "base"
    transport: str = "dense"
    local_update: str = "adam"
    server_update: str = "wmv"
    #: Wire encoding family (core/wire.py): ``mask_shared`` |
    #: ``mask_independent`` | ``sign`` | ``bbit`` | ``dense`` | None
    #: (no wire realization — dense transport, analytic bits only).
    wire_layout: Optional[str] = None

    # -- state ----------------------------------------------------------
    def init_state(self, params) -> Optional[Any]:
        """Per-client compressor state (error-feedback residuals etc.)
        for ONE client; ``fed_init`` stacks it over the client axis.
        ``None`` means the compressor is stateless."""
        return None

    # -- the operator ---------------------------------------------------
    def compress(self, deltas: Deltas, state) -> Tuple[Packed, Any, Any]:
        """``(packed, new_state, bits)``.  ``bits`` is the exact uplink
        bit count of this client's payload (static given tree shapes —
        matches ``n_clients * bits`` against core/comm.py formulas).
        Implementations MUST compute it as
        ``self.bits_per_client(tree_size(deltas.W))`` — the round's
        ``uplink_bits`` metric reads :meth:`bits_per_client` directly
        (once per round, outside the client scan/vmap), and routing both
        through one method is what makes drift impossible
        (``tests/test_compressors.py`` asserts their equality)."""
        raise NotImplementedError

    def decompress(self, packed: Packed) -> Deltas:
        """Server-side reconstruction to the dense triple.  The default
        inverts dense-carrier compressors (values already in place)."""
        return Deltas(packed.W, packed.M, packed.V)

    # -- wire realization ----------------------------------------------
    def pack_wire(self, carriers: Deltas) -> Optional[Any]:
        """Encode a dense carrier triple (the ``Packed.W/M/V`` planes, or
        equivalently the decoded outputs of :meth:`unpack_wire` — the
        encoding is idempotent) into the transported
        :class:`~repro.core.wire.WirePayload`.  Returns ``None`` when the
        configuration has no wire realization.  The buffered-async driver
        uses this to re-materialize the landed bytes per accepted update
        (:mod:`repro.core.async_fed`)."""
        return None

    def unpack_wire(self, wire, like) -> Deltas:
        """Decode a :class:`~repro.core.wire.WirePayload` produced by
        :meth:`compress` back to the dense carrier triple.  ``like`` is
        any tree with the model's structure/shapes/dtypes (the params
        template).  Only meaningful when :attr:`wire_layout` is set."""
        raise NotImplementedError(
            f"{self.name} has no wire realization")

    # -- accounting -----------------------------------------------------
    def bits_per_client(self, d: int) -> int:
        """Uplink bits ONE client pays per round for a d-dimensional
        model (Section IV / VII).  The round multiplies by the number of
        participating clients; must equal ``comm.bits_for(name, d, k, 1)``."""
        raise NotImplementedError

    def wire_bits_per_client(self, sizes) -> Optional[int]:
        """Measured wire bits ONE client pays per round, equal to
        ``8 * payload_nbytes`` of the payload :meth:`compress` builds
        for a tree with leaf ``sizes`` — or ``None`` when this
        configuration has no wire realization (the round metric then
        falls back to the analytic :meth:`bits_per_client`)."""
        return None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Compressor]] = {}


def register(name: str):
    """Decorator: register ``factory(fed_config) -> Compressor`` under an
    algorithm name.  ``fed_config`` is duck-typed (anything exposing the
    FedConfig fields the factory reads)."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def unregister(name: str) -> None:
    """Remove a registration (tests / plugin teardown)."""
    _REGISTRY.pop(name, None)


def available() -> Tuple[str, ...]:
    """Registered algorithm names, in registration order."""
    return tuple(_REGISTRY)


def make_compressor(fed) -> Compressor:
    """Build the compressor for ``fed.algorithm`` from its config."""
    try:
        factory = _REGISTRY[fed.algorithm]
    except KeyError:
        raise KeyError(
            f"no compressor registered for {fed.algorithm!r}; "
            f"known: {sorted(_REGISTRY)}") from None
    return factory(fed)


def transport_of(algorithm: str) -> str:
    """Transport tag of an algorithm's compressor (used by launchers to
    pick the aggregation path without building a round)."""
    from repro.core.fed import FedConfig
    return make_compressor(FedConfig(algorithm=algorithm)).transport
