"""Stateful uplink compressors + registry (see docs/compressors.md).

Importing this package registers every built-in algorithm; the import
order below fixes the canonical ``available()`` / ``ALGORITHMS`` order.
"""
from repro.core.compressors.base import (  # noqa: F401
    DIAG_KEYS,
    Compressor,
    Deltas,
    Packed,
    available,
    diag_metrics,
    make_compressor,
    register,
    transport_of,
    tree_add,
    tree_size,
    tree_sub,
    unregister,
    zero_diag,
)
from repro.core.compressors.topk import (  # noqa: F401
    IndependentTopKCompressor,
    SharedTopKCompressor,
)
from repro.core.compressors.dense import DenseCompressor  # noqa: F401
from repro.core.compressors.quantized import (  # noqa: F401
    EfficientAdamCompressor,
    OneBitAdamCompressor,
)
