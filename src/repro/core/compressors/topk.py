"""Top-k sparsifying compressors — FedAdam-SSM and its mask baselines.

``SharedTopKCompressor`` realizes the paper's contribution: ONE boolean
mask (Eq. 28: ``Top_k(|dW|)`` for rule ``ssm_w``; ``ssm_m``/``ssm_v``/
``fairness_top`` are the Section VII mask-rule baselines) applied to all
three deltas, so a single index set describes the support of W, M and V
— the alignment that makes the Section IV bit count
``N * min(3kq + d, k(3q + log2 d))`` instead of three index sets.

``IndependentTopKCompressor`` is FedAdam-Top: three separate Top_k masks,
three index sets, ``3N * min(kq + d, k(q + log2 d))`` bits.

Both optionally carry a beyond-paper error-feedback residual on dW: the
round's masked-away remainder is added back into the next round's input
(``init_state`` returns the zero residual; stateless when EF is off).

Hot path: with threshold masks (``exact_topk=False``) and the kernel
backend active (``sparsify_backend`` / REPRO_SPARSIFY_BACKEND, auto on
TPU), ``compress`` runs the PACKED Pallas pipeline: every pytree leaf
rides one tile-aligned buffer and the whole cohort costs exactly two
launches — a segmented tau histogram, then fused refine/tau-pick/mask
apply + ``value_dtype`` wire cast + EF residual
(``core/sparsify.tree_shared_compress_packed`` for the shared mask,
``tree_independent_compress_packed`` for FedAdam-Top's three masks) —
instead of 4 launches per leaf.  Backend rules, layout and launch
accounting: docs/kernels.md.

See ``docs/compressors.md`` for the protocol and bit formulas.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import comm, masks, wire
from repro.core import sparsify as S
from repro.core.compressors.base import (
    Compressor, Deltas, Packed, register, tree_add, tree_size, tree_sub,
)


def _cast_values(value_dtype, tree):
    """Beyond-paper low-precision value transport (cast + cast back)."""
    if value_dtype is None:
        return tree
    dt = jnp.dtype(value_dtype)
    return jax.tree.map(lambda x: x.astype(dt).astype(x.dtype), tree)


@dataclasses.dataclass(frozen=True)
class _TopKBase(Compressor):
    alpha: float = 0.05
    mask_scope: str = "per_tensor"        # per_tensor | global
    exact_topk: bool = True
    error_feedback: bool = False
    value_dtype: Optional[str] = None
    q_bits: int = 32
    # auto | kernel | reference — resolved by core/sparsify.resolve_backend
    # (TPU -> Pallas kernels, else jnp reference; env-overridable).  Only
    # the threshold (exact_topk=False) masks have a kernel realization.
    sparsify_backend: str = "auto"

    def init_state(self, params):
        if not self.error_feedback:
            return None
        return {"err": jax.tree.map(jnp.zeros_like, params)}

    def _masks(self, dW, dM, dV):
        raise NotImplementedError

    def _kernel_path(self) -> bool:
        return (not self.exact_topk) and \
            S.use_kernel_path(self.sparsify_backend)

    def _fused_compress(self, dW, dM, dV, with_residual):
        """Kernel-path fused compress.  Returns ``(sW, sM, sV,
        err_tree | None, mask)`` — ``mask`` is one shared tree
        (SharedTopK) or a ``(mW, mM, mV)`` tuple (IndependentTopK) —
        or None when the compressor has no fused realization for these
        inputs (e.g. mixed dtypes defeat the packed layout)."""
        return None

    def _wire_ok(self) -> bool:
        # wire value streams ship as f32 — exact only at q = 32
        return self.q_bits == wire.VALUE_BITS

    def _mask_capacity(self, sizes) -> int:
        return wire.mask_value_capacity(sizes, self.alpha,
                                        self.mask_scope, self.exact_topk)

    def _pack_wire(self, sW, sM, sV, sizes):
        raise NotImplementedError

    def compress(self, deltas: Deltas, state):
        dW, dM, dV = deltas
        if state is not None:
            dW = tree_add(dW, state["err"])
        fused = self._fused_compress(dW, dM, dV, state is not None) \
            if self._kernel_path() else None
        if fused is not None:
            # ONE streaming pipeline: mask apply on all three deltas, the
            # value_dtype wire cast and the EF residual — two packed
            # launches for the whole cohort instead of 4 per leaf
            # (docs/kernels.md).  Independent compressors return a
            # (mW, mM, mV) tuple; shared compressors one mask for all.
            sW, sM, sV, err, m = fused
            if isinstance(m, tuple):
                mW, mM, mV = m
            else:
                mW = mM = mV = m
            new_state = {"err": err} if state is not None else None
        else:
            mW, mM, mV = self._masks(dW, dM, dV)
            sW = _cast_values(self.value_dtype, S.tree_sparsify(dW, mW))
            sM = _cast_values(self.value_dtype, S.tree_sparsify(dM, mM))
            sV = _cast_values(self.value_dtype, S.tree_sparsify(dV, mV))
            new_state = {"err": tree_sub(dW, sW)} \
                if state is not None else None
        diag = {
            "err_w": S.tree_sparsity_error(dW, mW),
            "err_m": S.tree_sparsity_error(dM, mM),
            "err_v": S.tree_sparsity_error(dV, mV),
            "norm_dw": S.tree_norm(dW),
            "norm_dm": S.tree_norm(dM),
            "norm_dv": S.tree_norm(dV),
        }
        packed = Packed(sW, sM, sV, diag, self.pack_wire(Deltas(sW, sM, sV)))
        return packed, new_state, self.bits_per_client(tree_size(deltas.W))

    def pack_wire(self, carriers: Deltas):
        # idempotent: the sparse carriers' union support IS the mask, so
        # re-encoding a decoded triple reproduces the payload bitwise
        # (what lets the async driver re-materialize landed bytes)
        if not self._wire_ok():
            return None
        sizes = tuple(x.size for x in jax.tree.leaves(carriers.W))
        return self._pack_wire(carriers.W, carriers.M, carriers.V, sizes)


@dataclasses.dataclass(frozen=True)
class SharedTopKCompressor(_TopKBase):
    """One shared mask for all three tensors (FedAdam-SSM family)."""

    name: str = "fedadam_ssm"
    rule: str = "ssm_w"                   # ssm_w | ssm_m | ssm_v | fairness_top

    transport = "shared_sparse"
    wire_layout = "mask_shared"

    def _masks(self, dW, dM, dV):
        m = masks.shared_mask(self.rule, dW, dM, dV, self.alpha,
                              self.mask_scope, self.exact_topk,
                              backend=self.sparsify_backend)
        return m, m, m

    def _fused_compress(self, dW, dM, dV, with_residual):
        score = masks.shared_score_tree(self.rule, dW, dM, dV)
        sW, sM, sV, err, m = S.tree_shared_compress_fused(
            score, dW, dM, dV, self.alpha, self.mask_scope,
            value_dtype=self.value_dtype, with_residual=with_residual)
        return sW, sM, sV, err, m

    def _pack_wire(self, sW, sM, sV, sizes):
        return wire.pack_shared_mask(sW, sM, sV, self._mask_capacity(sizes))

    def unpack_wire(self, payload, like) -> Deltas:
        return Deltas(*wire.unpack_shared_mask(payload, like))

    def bits_per_client(self, d: int) -> int:
        return comm.bits_fedadam_ssm(d, S.k_for(d, self.alpha), 1,
                                     self.q_bits)

    def wire_bits_per_client(self, sizes):
        if not self._wire_ok():
            return None
        return wire.mask_wire_bits(sizes, self.alpha, self.mask_scope,
                                   self.exact_topk, shared=True)


@dataclasses.dataclass(frozen=True)
class IndependentTopKCompressor(_TopKBase):
    """Three independent Top_k masks (FedAdam-Top)."""

    name: str = "fedadam_top"

    transport = "independent_sparse"
    wire_layout = "mask_independent"

    def _masks(self, dW, dM, dV):
        # three distinct masks — no shared-mask fusion, but the mask
        # construction itself still dispatches to the threshold kernel
        return masks.independent_masks(dW, dM, dV, self.alpha,
                                       self.mask_scope, self.exact_topk,
                                       backend=self.sparsify_backend)

    def _fused_compress(self, dW, dM, dV, with_residual):
        # three independent selections still collapse to TWO launches:
        # all leaves of dW ++ dM ++ dV share one packed buffer whose
        # segments each pick their own tau (core/sparsify)
        if not S._uniform_dtype(dW, dM, dV):
            return None
        return S.tree_independent_compress_packed(
            dW, dM, dV, self.alpha, self.mask_scope,
            value_dtype=self.value_dtype, with_residual=with_residual)

    def _pack_wire(self, sW, sM, sV, sizes):
        return wire.pack_independent_mask(sW, sM, sV,
                                          self._mask_capacity(sizes))

    def unpack_wire(self, payload, like) -> Deltas:
        return Deltas(*wire.unpack_independent_mask(payload, like))

    def bits_per_client(self, d: int) -> int:
        return comm.bits_fedadam_top(d, S.k_for(d, self.alpha), 1,
                                     self.q_bits)

    def wire_bits_per_client(self, sizes):
        if not self._wire_ok():
            return None
        return wire.mask_wire_bits(sizes, self.alpha, self.mask_scope,
                                   self.exact_topk, shared=False)


def _shared_factory(rule):
    def factory(fed) -> SharedTopKCompressor:
        return SharedTopKCompressor(
            name=fed.algorithm, rule=rule, alpha=fed.alpha,
            mask_scope=fed.mask_scope, exact_topk=fed.exact_topk,
            error_feedback=fed.error_feedback, value_dtype=fed.value_dtype,
            q_bits=fed.q_bits, sparsify_backend=fed.sparsify_backend)
    return factory


register("fedadam_ssm")(_shared_factory("ssm_w"))
register("ssm_m")(_shared_factory("ssm_m"))
register("ssm_v")(_shared_factory("ssm_v"))
register("fairness_top")(_shared_factory("fairness_top"))


@register("fedadam_top")
def _fedadam_top(fed) -> IndependentTopKCompressor:
    return IndependentTopKCompressor(
        name="fedadam_top", alpha=fed.alpha, mask_scope=fed.mask_scope,
        exact_topk=fed.exact_topk, error_feedback=fed.error_feedback,
        value_dtype=fed.value_dtype, q_bits=fed.q_bits,
        sparsify_backend=fed.sparsify_backend)
