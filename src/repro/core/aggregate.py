"""Server-side aggregation of per-client deltas, in two HLO-visible forms.

``dense``          — weighted sum over the client axis of dense (masked)
                     deltas.  When the client axis is sharded over mesh axes
                     this lowers to an ALL-REDUCE of the full model: the
                     FedAdam baseline's uplink, ~2*d*q bytes/link.
``sparse_gather``  — per client, pack the k kept values (+ one shared index
                     vector for all three tensors — the SSM alignment!) and
                     ALL-GATHER the packed representation; every client then
                     replays the server scatter-add locally.  Collective
                     bytes drop from O(d*q) to O(N*k*(3q + log d)) — the
                     paper's Section-IV uplink saving realized on ICI.

Napkin math (per link, bf16 values, int32 indices, alpha=0.05, N=16):
  dense all-reduce of 3 tensors : ~2 * 3d * 2B       = 12 d bytes
  SSM sparse all-gather         : 16 * 0.05d * (3*2+4)B = 8 d bytes
  Top (3 index sets)            : 16 * 0.05d * 3*(2+4)B = 14.4 d bytes
i.e. on a 16-client axis the SHARED mask is exactly what keeps the sparse
transport under the dense baseline — FedAdam-Top's independent masks are
*worse* than dense at this (alpha, N).  With N=2 pod-clients the SSM gather
is ~12x under dense.  (Recorded in EXPERIMENTS.md §Transport.)

Entry point for the round: ``packed_gather_sum`` dispatches on the
compressor's ``transport`` tag (docs/compressors.md), so new compressors
ride the sparse transport without edits here.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import sparsify as S
from repro.kernels.topk_mask.ops import overselect_bound

_F32 = jnp.float32


def _maybe_replicate(x):
    """Force replication across the mesh (the all-gather) when tracing
    under a mesh; no-op in plain CPU tests."""
    try:
        return lax.with_sharding_constraint(x, PartitionSpec())
    except Exception:
        return x


def dense_weighted_sum(tree_c, weights):
    """tree_c: leaves (C, ...); returns weighted sum over C."""
    return jax.tree.map(
        lambda x: jnp.tensordot(weights.astype(_F32), x.astype(_F32),
                                axes=(0, 0)), tree_c)


def ordered_weighted_sum(tree_c, weights):
    """Weighted sum over the leading client axis with ``round_scan``'s
    exact accumulation order and arithmetic (``acc + w * x.astype(f32)``,
    client 0 first), so the mesh driver's dense aggregation is
    bit-identical to the scan reference (tests/test_fed_equivalence.py).
    The buffered-async driver's server step (core/async_fed.py) runs
    its K-update buffer through this same fold in arrival order, which
    is what makes its zero-churn degenerate config bit-identical to the
    sync round too.  O(C) sequential adds — the reference/debug
    aggregation; the production uplink is the sparse shard_map
    transport."""
    zero = jax.tree.map(lambda x: jnp.zeros(x.shape[1:], _F32), tree_c)

    def body(acc, xs):
        x, w = xs
        return jax.tree.map(
            lambda a, y: a + w * y.astype(_F32), acc, x), 0.0

    acc, _ = lax.scan(body, zero, (tree_c, weights))
    return acc


def _to_blocks(x_c, n):
    """(C, n) -> (C, nb, B) zero-padded; B per core/sparsify.BLOCK."""
    B = S.BLOCK
    C = x_c.shape[0]
    nb = -(-n // B)
    pad = nb * B - n
    return jnp.pad(x_c, ((0, 0), (0, pad))).reshape(C, nb, B), nb, B


def _capacity(n, B, alpha):
    """Per-block packed capacity: threshold masks over-select by ties/bin
    width, so size the pack for the kernel contract's worst case —
    ``k + overselect_bound(k)`` (kernels/topk_mask/ops.py, the single
    source of truth; see docs/kernels.md).  Overflow beyond capacity is
    dropped and accounted — reported by fed metrics."""
    size = B if n > B else n
    base = S.k_for(size, alpha)
    return min(size, base + overselect_bound(base))


def _pack(x_c, n, alpha, *, sort_free: bool = True):
    """Pack the nonzeros of masked dense deltas into a fixed-capacity COO.

    x_c: (C, n) masked dense -> (vals (C, nb, kb), idx (C, nb, kb) int32
    block-local).  sort_free=True (production): prefix-sum position
    assignment — O(n), no sort temps.  sort_free=False: exact |.| top-k
    per block (sort-based; small models / tests)."""
    xb, nb, B = _to_blocks(x_c, n)
    C = xb.shape[0]
    if not sort_free:
        kb = S.k_for(B, alpha) if n > B else S.k_for(n, alpha)
        _, idx = lax.top_k(jnp.abs(xb.astype(_F32)), kb)
        vals = jnp.take_along_axis(xb, idx, axis=2)
        return vals, idx, jnp.ones(vals.shape, bool)
    kb = _capacity(n, B, alpha)
    m = xb != 0
    pos = jnp.cumsum(m.astype(jnp.int32), axis=-1) - 1        # (C, nb, B)
    keep = m & (pos < kb)
    dst = jnp.where(keep, pos, kb)                            # kb = drop slot
    src_idx = jnp.broadcast_to(
        jnp.arange(B, dtype=jnp.int32)[None, None, :], xb.shape)
    ci = jnp.broadcast_to(jnp.arange(C)[:, None, None], xb.shape)
    ri = jnp.broadcast_to(jnp.arange(nb)[None, :, None], xb.shape)
    vals = jnp.zeros((C, nb, kb + 1), xb.dtype) \
        .at[ci, ri, dst].set(xb, mode="drop")[..., :kb]
    # store index+1 so empty capacity slots are detectable (idx_plus == 0)
    idx_plus = jnp.zeros((C, nb, kb + 1), jnp.int32) \
        .at[ci, ri, dst].set(src_idx + 1, mode="drop")[..., :kb]
    valid = idx_plus > 0
    idx = jnp.maximum(idx_plus - 1, 0)
    return vals, idx, valid


def _scatter_weighted(vals, idx, valid, weights, n):
    """vals/idx/valid: (C, nb, kb) replicated; dense (n,) weighted sum."""
    C, nb, kb = vals.shape
    B = S.BLOCK if n > S.BLOCK else -(-n // nb)
    wv = vals.astype(_F32) * weights.astype(_F32)[:, None, None]
    wv = jnp.where(valid, wv, 0.0)
    rows = jnp.broadcast_to(jnp.arange(nb)[None, :, None], idx.shape)
    out = jnp.zeros((nb, B), _F32)
    out = out.at[rows.reshape(-1), idx.reshape(-1)].add(wv.reshape(-1))
    return out.reshape(-1)[:n]


def sparse_shared_gather_sum(sW_c, sM_c, sV_c, alpha, weights,
                             value_dtype=None, sort_free=True):
    """FedAdam-SSM transport: ONE index vector per tensor-leaf per client
    (from the shared mask), three value vectors.  All-gather the packed
    (3k values + k indices), scatter-add locally."""

    def leaf(w_c, m_c, v_c):
        C = w_c.shape[0]
        n = int(math.prod(w_c.shape[1:])) if w_c.ndim > 1 else 1
        # ONE index set from dW's mask (the shared mask), three value sets
        vw, idx, valid = _pack(w_c.reshape(C, n), n, alpha,
                               sort_free=sort_free)
        mf, _, _ = _to_blocks(m_c.reshape(C, n), n)
        vf, _, _ = _to_blocks(v_c.reshape(C, n), n)
        take = lambda t: jnp.take_along_axis(t, idx, axis=2)
        vm, vv = take(mf), take(vf)
        if value_dtype is not None:
            dt = jnp.dtype(value_dtype)
            vw, vm, vv = (t.astype(dt) for t in (vw, vm, vv))
        # the uplink: replicate the packed representation (all-gather)
        idx = _maybe_replicate(idx)
        valid = _maybe_replicate(valid)
        vw, vm, vv = map(_maybe_replicate, (vw, vm, vv))
        shape = w_c.shape[1:]
        return (
            _scatter_weighted(vw, idx, valid, weights, n).reshape(shape),
            _scatter_weighted(vm, idx, valid, weights, n).reshape(shape),
            _scatter_weighted(vv, idx, valid, weights, n).reshape(shape),
        )

    # explicit flatten/unflatten: the tree may itself contain tuples
    lw, treedef = jax.tree_util.tree_flatten(sW_c)
    lm = treedef.flatten_up_to(sM_c)
    lv = treedef.flatten_up_to(sV_c)
    outs = [leaf(w, m, v) for w, m, v in zip(lw, lm, lv)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
            treedef.unflatten([o[2] for o in outs]))


# ---------------------------------------------------------------------------
# shard_map realization — the production path
# ---------------------------------------------------------------------------
#
# In global-view jnp, GSPMD turns the pack's scatter into replicated giant
# index tensors (observed: s32[16,1080,1M,3] all-gathers).  Under shard_map
# the pack is a *local* O(n_loc) program per device and the ONLY collective
# is the explicit all-gather of the packed (values, indices) — byte-for-byte
# the paper's uplink.  Each (data-row, model-col) device packs its own
# client's slice of its own model shard; after the gather over the client
# axes, every device scatter-adds the C packs into its local dense shard:
# no model-axis communication at all (the server reduction is replayed
# shard-locally).


def _local_pack(wf, alpha):
    """wf: (n_loc,) masked dense, device-local.  -> (vals, idx, valid)."""
    n = wf.shape[0]
    # capacity per the over-selection contract, as in _capacity above
    k = S.k_for(n, alpha)
    kb = min(n, k + overselect_bound(k))
    m = wf != 0
    pos = jnp.cumsum(m.astype(jnp.int32)) - 1
    keep = m & (pos < kb)
    dst = jnp.where(keep, pos, kb)
    vals = jnp.zeros((kb + 1,), wf.dtype).at[dst].set(wf, mode="drop")
    idxp = jnp.zeros((kb + 1,), jnp.int32).at[dst].set(
        jnp.arange(n, dtype=jnp.int32) + 1, mode="drop")
    return vals[:kb], jnp.maximum(idxp[:kb] - 1, 0), idxp[:kb] > 0


def _gathered_scatter(vals_g, idx_g, valid_g, weights, n_loc):
    """vals_g/idx_g/valid_g: (C, kb) post-gather; -> (n_loc,) f32 sum."""
    wv = vals_g.astype(_F32) * weights.astype(_F32)[:, None]
    wv = jnp.where(valid_g, wv, 0.0)
    out = jnp.zeros((n_loc,), _F32)
    return out.at[idx_g.reshape(-1)].add(wv.reshape(-1))


def make_shardmap_sparse_aggregate(mesh, param_pspecs, client_axes, alpha,
                                   *, shared: bool = True,
                                   value_dtype=None):
    """Build the shard_map sparse-transport aggregation::

        agg(sW_c, sM_c, sV_c, weights)           -> (aW, aM, aV)
        agg(sW_c, sM_c, sV_c, weights, comp_err) -> (aW, aM, aV), new_err

    (weighted SUMS).  param_pspecs: pytree of PartitionSpec for the
    *unstacked* params; the client-stacked inputs get
    P(client_axes, *param_spec).

    ``comp_err`` (optional) is the per-shard error-feedback residual tree
    on dW (client-stacked, same treedef as the params), as carried by the
    shard_map round driver under ``client_state["comp"]["err"]``.  When
    given, values the fixed-capacity pack DROPS from the wire (capacity =
    k + overselect_bound(k) per device shard; overflow beyond it never
    reaches the server) are added back into the residual, so transport
    drop obeys the same error-feedback semantics as mask drop instead of
    silently vanishing.  When nothing overflows the residual is returned
    bit-unchanged."""
    from repro.compat import shard_map

    caxes = tuple(client_axes)
    cax_entry = caxes if len(caxes) > 1 else caxes[0]

    leaves_spec, treedef = jax.tree_util.tree_flatten(
        param_pspecs, is_leaf=lambda x: isinstance(x, PartitionSpec))
    stacked_spec = treedef.unflatten(
        [PartitionSpec(cax_entry, *sp) for sp in leaves_spec])
    wspec = PartitionSpec(None)
    vdt = jnp.dtype(value_dtype) if value_dtype else None

    def body(w_tree, m_tree, v_tree, weights, err_tree):
        lw = jax.tree_util.tree_leaves(w_tree)
        lm = jax.tree_util.tree_leaves(m_tree)
        lv = jax.tree_util.tree_leaves(v_tree)
        lerr = jax.tree_util.tree_leaves(err_tree)
        has_err = len(lerr) > 0    # list emptiness: static at trace time
        outs_w, outs_m, outs_v, outs_err = [], [], [], []
        for i, (w, m, v) in enumerate(zip(lw, lm, lv)):
            c_loc = w.shape[0]
            assert c_loc == 1, "one spatial client per device row"
            shape_loc = w.shape[1:]
            n_loc = 1
            for sdim in shape_loc:
                n_loc *= sdim
            wf = w.reshape(n_loc)
            vals_w, idx, valid = _local_pack(wf, alpha)
            take = lambda t: jnp.where(
                valid, jnp.take(t.reshape(n_loc), idx), 0)
            vals_m, vals_v = take(m), take(v)
            if vdt is not None:
                vals_w = vals_w.astype(vdt)
                vals_m = vals_m.astype(vdt)
                vals_v = vals_v.astype(vdt)
            if has_err:
                # what the server actually receives for this client: the
                # (possibly wire-cast) packed values scattered back; the
                # capacity-overflow remainder feeds the EF residual
                kept = jnp.zeros((n_loc,), _F32).at[idx].add(
                    jnp.where(valid, vals_w.astype(_F32), 0.0))
                err = lerr[i].reshape(n_loc)
                # drop first, then add: when nothing overflows the drop is
                # exactly 0.0 and the residual passes through bitwise
                drop = wf.astype(_F32) - kept
                new_err = (err.astype(_F32) + drop).astype(err.dtype)
                outs_err.append(new_err.reshape(lerr[i].shape))
            # THE UPLINK: all-gather packed representation over client axes
            gather = lambda t: _gather_clients(t, caxes)
            vw_g, idx_g, valid_g = gather(vals_w), gather(idx), gather(valid)
            outs_w.append(_gathered_scatter(vw_g, idx_g, valid_g, weights,
                                            n_loc).reshape(shape_loc))
            if shared:
                vm_g, vv_g = gather(vals_m), gather(vals_v)
                outs_m.append(_gathered_scatter(
                    vm_g, idx_g, valid_g, weights, n_loc).reshape(shape_loc))
                outs_v.append(_gathered_scatter(
                    vv_g, idx_g, valid_g, weights, n_loc).reshape(shape_loc))
            else:
                # independent masks: re-pack m and v with their own indices
                for src, sink in ((m, outs_m), (v, outs_v)):
                    va, ix, vd = _local_pack(src.reshape(n_loc), alpha)
                    if vdt is not None:
                        va = va.astype(vdt)
                    sink.append(_gathered_scatter(
                        gather(va), gather(ix), gather(vd), weights,
                        n_loc).reshape(shape_loc))
        unf = lambda leaves: jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(w_tree), leaves)
        new_err_tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(err_tree), outs_err) \
            if has_err else None
        return unf(outs_w), unf(outs_m), unf(outs_v), new_err_tree

    def agg(sW_c, sM_c, sV_c, weights, comp_err=None):
        has_err = comp_err is not None
        err_spec = stacked_spec if has_err else None
        aW, aM, aV, new_err = shard_map(
            body, mesh=mesh,
            in_specs=(stacked_spec, stacked_spec, stacked_spec, wspec,
                      err_spec),
            out_specs=(param_pspecs, param_pspecs, param_pspecs,
                       err_spec),
            check_vma=False,
        )(sW_c, sM_c, sV_c, weights, comp_err)
        if has_err:
            return (aW, aM, aV), new_err
        return aW, aM, aV

    return agg


def _gather_clients(x, caxes):
    """all_gather over the client mesh axes -> (C, *x.shape).  The gather
    order (axis-tuple order) matches the row-major client linearization of
    the batch sharding P(caxes, ...)."""
    name = caxes if len(caxes) > 1 else caxes[0]
    return jax.lax.all_gather(x, name, axis=0, tiled=False)


def packed_gather_sum(compressor, sW_c, sM_c, sV_c, weights, *, alpha,
                      value_dtype=None, sort_free=True):
    """Aggregate any compressor's packed representation, keyed on its
    ``transport`` tag (see core/compressors and docs/compressors.md):

    * ``shared_sparse``      — one index set per client-leaf, three value
                               sets (FedAdam-SSM family).
    * ``independent_sparse`` — three (values, indices) packs per leaf
                               (FedAdam-Top).
    * anything else          — dense weighted sum (identity / quantized
                               carriers have no sparse structure to pack).

    New compressors therefore get the sparse all-gather path for free by
    declaring the matching transport.
    """
    t = getattr(compressor, "transport", "dense")
    if t == "shared_sparse":
        return sparse_shared_gather_sum(sW_c, sM_c, sV_c, alpha, weights,
                                        value_dtype, sort_free)
    if t == "independent_sparse":
        agg = lambda tr: sparse_independent_gather_sum(
            tr, alpha, weights, value_dtype, sort_free)
        return agg(sW_c), agg(sM_c), agg(sV_c)
    return (dense_weighted_sum(sW_c, weights),
            dense_weighted_sum(sM_c, weights),
            dense_weighted_sum(sV_c, weights))


def sparse_independent_gather_sum(tree_c, alpha, weights, value_dtype=None,
                                  sort_free=True):
    """FedAdam-Top transport: per-tensor independent (values, indices)."""

    def leaf(x_c):
        C = x_c.shape[0]
        n = int(math.prod(x_c.shape[1:])) if x_c.ndim > 1 else 1
        vals, idx, valid = _pack(x_c.reshape(C, n), n, alpha,
                                 sort_free=sort_free)
        if value_dtype is not None:
            vals = vals.astype(jnp.dtype(value_dtype))
        vals = _maybe_replicate(vals)
        idx = _maybe_replicate(idx)
        valid = _maybe_replicate(valid)
        return _scatter_weighted(vals, idx, valid, weights, n) \
            .reshape(x_c.shape[1:])

    return jax.tree.map(leaf, tree_c)
