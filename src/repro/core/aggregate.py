"""Server-side aggregation of per-client deltas, in two HLO-visible forms.

``dense``          — weighted sum over the client axis of dense (masked)
                     deltas.  When the client axis is sharded over mesh axes
                     this lowers to an ALL-REDUCE of the full model: the
                     FedAdam baseline's uplink, ~2*d*q bytes/link.
``sparse_gather``  — per client, pack the wire representation — a uint32
                     support bitmap (ONE bitmap for all three tensors —
                     the SSM alignment!) + the k kept values — and
                     ALL-GATHER it; every client then replays the server
                     fold locally.  Collective bytes drop from O(d*q) to
                     O(N*(d/8 + 3kq/8)) — the paper's Section-IV uplink
                     saving realized on ICI, byte-for-byte the reported
                     ``uplink_bits`` (core/wire.py).

Napkin math (per link, bf16 values, int32 indices, alpha=0.05, N=16):
  dense all-reduce of 3 tensors : ~2 * 3d * 2B       = 12 d bytes
  SSM sparse all-gather         : 16 * 0.05d * (3*2+4)B = 8 d bytes
  Top (3 index sets)            : 16 * 0.05d * 3*(2+4)B = 14.4 d bytes
i.e. on a 16-client axis the SHARED mask is exactly what keeps the sparse
transport under the dense baseline — FedAdam-Top's independent masks are
*worse* than dense at this (alpha, N).  With N=2 pod-clients the SSM gather
is ~12x under dense.  (Recorded in EXPERIMENTS.md §Transport.)

Entry point for the round: ``packed_gather_sum`` dispatches on the
compressor's ``transport`` tag (docs/compressors.md), so new compressors
ride the sparse transport without edits here.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import sparsify as S
from repro.kernels.topk_mask.ops import overselect_bound

_F32 = jnp.float32


def _maybe_replicate(x):
    """Force replication across the mesh (the all-gather) when tracing
    under a mesh; no-op in plain CPU tests."""
    try:
        return lax.with_sharding_constraint(x, PartitionSpec())
    except Exception:
        return x


def dense_weighted_sum(tree_c, weights):
    """tree_c: leaves (C, ...); returns weighted sum over C."""
    return jax.tree.map(
        lambda x: jnp.tensordot(weights.astype(_F32), x.astype(_F32),
                                axes=(0, 0)), tree_c)


def ordered_weighted_sum(tree_c, weights):
    """Weighted sum over the leading client axis with ``round_scan``'s
    exact accumulation order and arithmetic (``acc + w * x.astype(f32)``,
    client 0 first), so the mesh driver's dense aggregation is
    bit-identical to the scan reference (tests/test_fed_equivalence.py).
    The buffered-async driver's server step (core/async_fed.py) runs
    its K-update buffer through this same fold in arrival order, which
    is what makes its zero-churn degenerate config bit-identical to the
    sync round too.  O(C) sequential adds — the reference/debug
    aggregation; the production uplink is the sparse shard_map
    transport."""
    zero = jax.tree.map(lambda x: jnp.zeros(x.shape[1:], _F32), tree_c)

    def body(acc, xs):
        x, w = xs
        return jax.tree.map(
            lambda a, y: a + w * y.astype(_F32), acc, x), 0.0

    acc, _ = lax.scan(body, zero, (tree_c, weights))
    return acc


def _to_blocks(x_c, n):
    """(C, n) -> (C, nb, B) zero-padded; B per core/sparsify.BLOCK."""
    B = S.BLOCK
    C = x_c.shape[0]
    nb = -(-n // B)
    pad = nb * B - n
    return jnp.pad(x_c, ((0, 0), (0, pad))).reshape(C, nb, B), nb, B


def _capacity(n, B, alpha):
    """Per-block packed capacity: threshold masks over-select by ties/bin
    width, so size the pack for the kernel contract's worst case —
    ``k + overselect_bound(k)`` (kernels/topk_mask/ops.py, the single
    source of truth; see docs/kernels.md).  Overflow beyond capacity is
    dropped and accounted — reported by fed metrics."""
    size = B if n > B else n
    base = S.k_for(size, alpha)
    return min(size, base + overselect_bound(base))


def _pack(x_c, n, alpha, *, sort_free: bool = True):
    """Pack the nonzeros of masked dense deltas into a fixed-capacity COO.

    x_c: (C, n) masked dense -> (vals (C, nb, kb), idx (C, nb, kb) int32
    block-local).  sort_free=True (production): prefix-sum position
    assignment — O(n), no sort temps.  sort_free=False: exact |.| top-k
    per block (sort-based; small models / tests)."""
    xb, nb, B = _to_blocks(x_c, n)
    C = xb.shape[0]
    if not sort_free:
        kb = S.k_for(B, alpha) if n > B else S.k_for(n, alpha)
        _, idx = lax.top_k(jnp.abs(xb.astype(_F32)), kb)
        vals = jnp.take_along_axis(xb, idx, axis=2)
        return vals, idx, jnp.ones(vals.shape, bool)
    kb = _capacity(n, B, alpha)
    m = xb != 0
    pos = jnp.cumsum(m.astype(jnp.int32), axis=-1) - 1        # (C, nb, B)
    keep = m & (pos < kb)
    dst = jnp.where(keep, pos, kb)                            # kb = drop slot
    src_idx = jnp.broadcast_to(
        jnp.arange(B, dtype=jnp.int32)[None, None, :], xb.shape)
    ci = jnp.broadcast_to(jnp.arange(C)[:, None, None], xb.shape)
    ri = jnp.broadcast_to(jnp.arange(nb)[None, :, None], xb.shape)
    vals = jnp.zeros((C, nb, kb + 1), xb.dtype) \
        .at[ci, ri, dst].set(xb, mode="drop")[..., :kb]
    # store index+1 so empty capacity slots are detectable (idx_plus == 0)
    idx_plus = jnp.zeros((C, nb, kb + 1), jnp.int32) \
        .at[ci, ri, dst].set(src_idx + 1, mode="drop")[..., :kb]
    valid = idx_plus > 0
    idx = jnp.maximum(idx_plus - 1, 0)
    return vals, idx, valid


def _scatter_weighted(vals, idx, valid, weights, n):
    """vals/idx/valid: (C, nb, kb) replicated; dense (n,) weighted sum."""
    C, nb, kb = vals.shape
    B = S.BLOCK if n > S.BLOCK else -(-n // nb)
    wv = vals.astype(_F32) * weights.astype(_F32)[:, None, None]
    wv = jnp.where(valid, wv, 0.0)
    rows = jnp.broadcast_to(jnp.arange(nb)[None, :, None], idx.shape)
    out = jnp.zeros((nb, B), _F32)
    out = out.at[rows.reshape(-1), idx.reshape(-1)].add(wv.reshape(-1))
    return out.reshape(-1)[:n]


def sparse_shared_gather_sum(sW_c, sM_c, sV_c, alpha, weights,
                             value_dtype=None, sort_free=True):
    """FedAdam-SSM transport: ONE index vector per tensor-leaf per client
    (from the shared mask), three value vectors.  All-gather the packed
    (3k values + k indices), scatter-add locally."""

    def leaf(w_c, m_c, v_c):
        C = w_c.shape[0]
        n = int(math.prod(w_c.shape[1:])) if w_c.ndim > 1 else 1
        # ONE index set from dW's mask (the shared mask), three value sets
        vw, idx, valid = _pack(w_c.reshape(C, n), n, alpha,
                               sort_free=sort_free)
        mf, _, _ = _to_blocks(m_c.reshape(C, n), n)
        vf, _, _ = _to_blocks(v_c.reshape(C, n), n)
        take = lambda t: jnp.take_along_axis(t, idx, axis=2)
        vm, vv = take(mf), take(vf)
        if value_dtype is not None:
            dt = jnp.dtype(value_dtype)
            vw, vm, vv = (t.astype(dt) for t in (vw, vm, vv))
        # the uplink: replicate the packed representation (all-gather)
        idx = _maybe_replicate(idx)
        valid = _maybe_replicate(valid)
        vw, vm, vv = map(_maybe_replicate, (vw, vm, vv))
        shape = w_c.shape[1:]
        return (
            _scatter_weighted(vw, idx, valid, weights, n).reshape(shape),
            _scatter_weighted(vm, idx, valid, weights, n).reshape(shape),
            _scatter_weighted(vv, idx, valid, weights, n).reshape(shape),
        )

    # explicit flatten/unflatten: the tree may itself contain tuples
    lw, treedef = jax.tree_util.tree_flatten(sW_c)
    lm = treedef.flatten_up_to(sM_c)
    lv = treedef.flatten_up_to(sV_c)
    outs = [leaf(w, m, v) for w, m, v in zip(lw, lm, lv)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
            treedef.unflatten([o[2] for o in outs]))


# ---------------------------------------------------------------------------
# shard_map realization — the production path
# ---------------------------------------------------------------------------
#
# In global-view jnp, GSPMD turns the pack's scatter into replicated giant
# index tensors (observed: s32[16,1080,1M,3] all-gathers).  Under shard_map
# the pack is a *local* O(n_loc) program per device and the ONLY collective
# is the explicit all-gather of the WIRE representation — a uint32 support
# bitmap (1 bit per local slot, core/wire.py word convention) plus the
# first-kb compacted f32 value stream.  No index tensor crosses the links:
# the receiver recomputes positions from the bitmap by prefix sum, so the
# gathered bytes are exactly the Section-IV count (d bits of mask + k q-bit
# values per client), matching ``8 * WirePayload.nbytes``.  Each (data-row,
# model-col) device packs its own client's slice of its own model shard;
# after the gather over the client axes, every device replays the server
# fold into its local dense shard: no model-axis communication at all.


def _local_pack(wf, alpha):
    """wf: (n_loc,) masked dense, device-local.  -> (words, pos, keep, kb):
    the support bitmap word-packed to uint32 + the compaction plan
    (prefix-sum positions, keep = supported and under capacity).
    Capacity kb per the over-selection contract, as in _capacity above."""
    from repro.core import wire
    n = wf.shape[0]
    k = S.k_for(n, alpha)
    kb = min(n, k + overselect_bound(k))
    m = wf != 0
    words = wire.pack_bits_1d(m)
    pos = jnp.cumsum(m.astype(jnp.int32)) - 1
    keep = m & (pos < kb)
    return words, pos, keep, kb


def _compact_vals(xf, pos, keep, kb):
    """First-kb compaction of ``xf`` onto the support plan (slot kb is
    the overflow drop slot, sliced away)."""
    dst = jnp.where(keep, pos, kb)
    return jnp.zeros((kb + 1,), _F32).at[dst].set(
        xf.astype(_F32), mode="drop")[:kb]


def _expand_vals(words, vals, n_loc):
    """Inverse of the (bitmap, stream) pack: (nw,) uint32 words + (kb,)
    values -> (n_loc,) f32 dense (capacity-overflow slots decode to 0)."""
    from repro.core import wire
    sup = wire.unpack_bits_1d(words, n_loc) == 1
    pos = jnp.cumsum(sup.astype(jnp.int32)) - 1
    kb = vals.shape[0]
    taken = jnp.take(vals.astype(_F32), jnp.clip(pos, 0, kb - 1))
    return jnp.where(sup & (pos < kb), taken, 0.0)


def _gathered_decode_sum(words_g, vals_g, weights, n_loc):
    """words_g (C, nw) + vals_g (C, kb) post-gather -> (n_loc,) f32
    weighted sum, folded in client order with ``round_scan``'s exact
    arithmetic (``acc + w * x``, client 0 first) so the mesh transport
    is bit-identical to the scan reference when nothing overflows."""
    def body(acc, xs):
        wrds, vals, wgt = xs
        return acc + wgt * _expand_vals(wrds, vals, n_loc), 0.0

    acc, _ = lax.scan(body, jnp.zeros((n_loc,), _F32),
                      (words_g, vals_g, weights.astype(_F32)))
    return acc


def make_shardmap_sparse_aggregate(mesh, param_pspecs, client_axes, alpha,
                                   *, shared: bool = True,
                                   value_dtype=None):
    """Build the shard_map sparse-transport aggregation::

        agg(sW_c, sM_c, sV_c, weights)           -> (aW, aM, aV)
        agg(sW_c, sM_c, sV_c, weights, comp_err) -> (aW, aM, aV), new_err

    (weighted SUMS).  param_pspecs: pytree of PartitionSpec for the
    *unstacked* params; the client-stacked inputs get
    P(client_axes, *param_spec).

    ``comp_err`` (optional) is the per-shard error-feedback residual tree
    on dW (client-stacked, same treedef as the params), as carried by the
    shard_map round driver under ``client_state["comp"]["err"]``.  When
    given, values the fixed-capacity pack DROPS from the wire (capacity =
    k + overselect_bound(k) per device shard; overflow beyond it never
    reaches the server) are added back into the residual, so transport
    drop obeys the same error-feedback semantics as mask drop instead of
    silently vanishing.  When nothing overflows the residual is returned
    bit-unchanged."""
    from repro.compat import shard_map

    caxes = tuple(client_axes)
    cax_entry = caxes if len(caxes) > 1 else caxes[0]

    leaves_spec, treedef = jax.tree_util.tree_flatten(
        param_pspecs, is_leaf=lambda x: isinstance(x, PartitionSpec))
    stacked_spec = treedef.unflatten(
        [PartitionSpec(cax_entry, *sp) for sp in leaves_spec])
    wspec = PartitionSpec(None)
    vdt = jnp.dtype(value_dtype) if value_dtype else None

    def body(w_tree, m_tree, v_tree, weights, err_tree):
        lw = jax.tree_util.tree_leaves(w_tree)
        lm = jax.tree_util.tree_leaves(m_tree)
        lv = jax.tree_util.tree_leaves(v_tree)
        lerr = jax.tree_util.tree_leaves(err_tree)
        has_err = len(lerr) > 0    # list emptiness: static at trace time
        outs_w, outs_m, outs_v, outs_err = [], [], [], []
        for i, (w, m, v) in enumerate(zip(lw, lm, lv)):
            c_loc = w.shape[0]
            assert c_loc == 1, "one spatial client per device row"
            shape_loc = w.shape[1:]
            n_loc = 1
            for sdim in shape_loc:
                n_loc *= sdim
            wf = w.reshape(n_loc)
            words, pos, keep, kb = _local_pack(wf, alpha)
            vals_w = _compact_vals(wf, pos, keep, kb)
            vals_m = _compact_vals(m.reshape(n_loc), pos, keep, kb)
            vals_v = _compact_vals(v.reshape(n_loc), pos, keep, kb)
            if vdt is not None:
                vals_w = vals_w.astype(vdt)
                vals_m = vals_m.astype(vdt)
                vals_v = vals_v.astype(vdt)
            if has_err:
                # what the server actually receives for this client: the
                # (possibly wire-cast) value stream expanded back onto the
                # bitmap; the capacity-overflow remainder feeds the EF
                # residual
                kept = jnp.where(
                    keep, jnp.take(vals_w.astype(_F32),
                                   jnp.clip(pos, 0, kb - 1)), 0.0)
                err = lerr[i].reshape(n_loc)
                # drop first, then add: when nothing overflows the drop is
                # exactly 0.0 and the residual passes through bitwise
                drop = wf.astype(_F32) - kept
                new_err = (err.astype(_F32) + drop).astype(err.dtype)
                outs_err.append(new_err.reshape(lerr[i].shape))
            # THE UPLINK: all-gather bitmap words + value streams over the
            # client axes — the only arrays that cross the links
            gather = lambda t: _gather_clients(t, caxes)
            words_g = gather(words)
            outs_w.append(_gathered_decode_sum(
                words_g, gather(vals_w), weights, n_loc).reshape(shape_loc))
            if shared:
                # the SSM alignment: ONE bitmap describes all three streams
                outs_m.append(_gathered_decode_sum(
                    words_g, gather(vals_m), weights,
                    n_loc).reshape(shape_loc))
                outs_v.append(_gathered_decode_sum(
                    words_g, gather(vals_v), weights,
                    n_loc).reshape(shape_loc))
            else:
                # independent masks: m and v ship their own bitmaps
                for src, sink in ((m, outs_m), (v, outs_v)):
                    sf = src.reshape(n_loc)
                    wds, ps, kp, cap = _local_pack(sf, alpha)
                    va = _compact_vals(sf, ps, kp, cap)
                    if vdt is not None:
                        va = va.astype(vdt)
                    sink.append(_gathered_decode_sum(
                        gather(wds), gather(va), weights,
                        n_loc).reshape(shape_loc))
        unf = lambda leaves: jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(w_tree), leaves)
        new_err_tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(err_tree), outs_err) \
            if has_err else None
        return unf(outs_w), unf(outs_m), unf(outs_v), new_err_tree

    def agg(sW_c, sM_c, sV_c, weights, comp_err=None):
        has_err = comp_err is not None
        err_spec = stacked_spec if has_err else None
        aW, aM, aV, new_err = shard_map(
            body, mesh=mesh,
            in_specs=(stacked_spec, stacked_spec, stacked_spec, wspec,
                      err_spec),
            out_specs=(param_pspecs, param_pspecs, param_pspecs,
                       err_spec),
            check_vma=False,
        )(sW_c, sM_c, sV_c, weights, comp_err)
        if has_err:
            return (aW, aM, aV), new_err
        return aW, aM, aV

    return agg


def _gather_clients(x, caxes):
    """all_gather over the client mesh axes -> (C, *x.shape).  The gather
    order (axis-tuple order) matches the row-major client linearization of
    the batch sharding P(caxes, ...)."""
    name = caxes if len(caxes) > 1 else caxes[0]
    return jax.lax.all_gather(x, name, axis=0, tiled=False)


def wire_gather_sum(compressor, payload_c, like, weights):
    """Aggregate client-stacked :class:`~repro.core.wire.WirePayload`\\ s:
    replicate the payload arrays (THE uplink — only bit-packed words and
    compact f32 value/scale streams cross the client axis), then decode
    and fold in client order with ``round_scan``'s exact arithmetic, so
    the vmap wire transport is bit-identical to the scan reference.
    ``like`` is the params template the decoder shapes against."""
    payload_c = jax.tree.map(_maybe_replicate, payload_c)
    zero = lambda: jax.tree.map(lambda x: jnp.zeros(x.shape, _F32), like)
    acc0 = (zero(), zero(), zero())

    def body(acc, xs):
        payload, wgt = xs
        sW, sM, sV = compressor.unpack_wire(payload, like)
        add = lambda a, s: jax.tree.map(
            lambda x, y: x + wgt * y.astype(_F32), a, s)
        aW, aM, aV = acc
        return (add(aW, sW), add(aM, sM), add(aV, sV)), 0.0

    (aW, aM, aV), _ = lax.scan(body, acc0,
                               (payload_c, weights.astype(_F32)))
    return aW, aM, aV


def packed_gather_sum(compressor, sW_c, sM_c, sV_c, weights, *, alpha,
                      value_dtype=None, sort_free=True,
                      payload_c=None, like=None):
    """Aggregate any compressor's packed representation.

    With ``payload_c`` (client-stacked WirePayloads from
    ``make_client_step(..., emit="wire")``) the transport is the wire
    format itself: :func:`wire_gather_sum` moves the bit-packed words
    across the client axis — the bytes ARE the reported
    ``8 * WirePayload.nbytes`` — for every wire-enabled scheme, sparse
    and quantized alike.

    Otherwise the legacy dense-carrier paths apply, keyed on the
    ``transport`` tag (see core/compressors and docs/compressors.md):

    * ``shared_sparse``      — one index set per client-leaf, three value
                               sets (FedAdam-SSM family).
    * ``independent_sparse`` — three (values, indices) packs per leaf
                               (FedAdam-Top).
    * anything else          — dense weighted sum (identity / quantized
                               carriers have no sparse structure to pack).

    New compressors therefore get the sparse all-gather path for free by
    declaring the matching transport (or the wire path by declaring a
    ``wire_layout``).
    """
    if payload_c is not None:
        return wire_gather_sum(compressor, payload_c, like, weights)
    t = getattr(compressor, "transport", "dense")
    if t == "shared_sparse":
        return sparse_shared_gather_sum(sW_c, sM_c, sV_c, alpha, weights,
                                        value_dtype, sort_free)
    if t == "independent_sparse":
        agg = lambda tr: sparse_independent_gather_sum(
            tr, alpha, weights, value_dtype, sort_free)
        return agg(sW_c), agg(sM_c), agg(sV_c)
    return (dense_weighted_sum(sW_c, weights),
            dense_weighted_sum(sM_c, weights),
            dense_weighted_sum(sV_c, weights))


def sparse_independent_gather_sum(tree_c, alpha, weights, value_dtype=None,
                                  sort_free=True):
    """FedAdam-Top transport: per-tensor independent (values, indices)."""

    def leaf(x_c):
        C = x_c.shape[0]
        n = int(math.prod(x_c.shape[1:])) if x_c.ndim > 1 else 1
        vals, idx, valid = _pack(x_c.reshape(C, n), n, alpha,
                                 sort_free=sort_free)
        if value_dtype is not None:
            vals = vals.astype(jnp.dtype(value_dtype))
        vals = _maybe_replicate(vals)
        idx = _maybe_replicate(idx)
        valid = _maybe_replicate(valid)
        return _scatter_weighted(vals, idx, valid, weights, n) \
            .reshape(x_c.shape[1:])

    return jax.tree.map(leaf, tree_c)
