"""FedAdam-SSM and baselines — Algorithms 1 & 2 of the paper.

One FL round (Algorithm 2):

1. every client starts local state from the global (W^t, M^t, V^t);
2. L local Adam epochs (Eqs. 3-5; no bias correction) on the client's data;
3. client deltas  dW = w - W^t, dM = m - M^t, dV = v - V^t;
4. compression:   a SHARED sparse mask (Eq. 28: mask = Top_k(|dW|)) applied
   to all three deltas (FedAdam-SSM), or per-algorithm alternatives;
5. server FedAvg over the sparse deltas; globals advance by the aggregate.

The paper's Algorithm 2 downloads the *previous* round's aggregate at the
start of the next round; applying the aggregate at the end of the current
round is the same sequence of states (the lag is only a pipelining detail),
which is how we implement it.

The round function is architecture-agnostic: it sees an abstract
``loss_fn(params, batch) -> scalar`` and parameter pytrees, so every
architecture in the zoo trains with the technique unchanged.

Client execution modes
----------------------
* ``scan``  — virtual clients: sequential ``lax.scan`` over the client axis
  (memory = one client); the mesh parallelizes *within* a client.
* ``vmap``  — spatial clients: the leading client axis of the batch is
  sharded over mesh axes ("data"/"pod"); per-client local training runs
  under ``vmap`` so divergent client replicas coexist, and the aggregation
  reduce IS the uplink collective (see core/aggregate.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import aggregate, comm, masks, quantize
from repro.core import sparsify as S
from repro.optim.adam import AdamHyper, AdamState, adam_step, sgd_step

_F32 = jnp.float32

ALGORITHMS = (
    "fedadam_ssm",     # the paper's contribution (mask rule ssm_w)
    "ssm_m",           # baseline: shared mask from |dM|
    "ssm_v",           # baseline: shared mask from |dV|
    "fairness_top",    # baseline: shared mask from the normalized union
    "fedadam_top",     # baseline: three independent top-k masks
    "fedadam",         # baseline: dense FedAdam (alpha=1 special case)
    "fedsgd",          # baseline: dense FedSGD
    "onebit_adam",     # baseline: 1-bit Adam (warmup + frozen precondition)
    "efficient_adam",  # baseline: two-way quantized Adam with EF
)

_RULE_OF = {"fedadam_ssm": "ssm_w", "ssm_m": "ssm_m", "ssm_v": "ssm_v",
            "fairness_top": "fairness_top"}


@dataclasses.dataclass(frozen=True)
class FedConfig:
    algorithm: str = "fedadam_ssm"
    alpha: float = 0.05                   # sparsification ratio k/d
    local_epochs: int = 30
    n_clients: int = 20
    adam: AdamHyper = AdamHyper()
    mask_scope: str = "per_tensor"        # per_tensor | global
    exact_topk: bool = True               # exact sort vs threshold bisection
    error_feedback: bool = False          # beyond-paper for sparse algos
    quant_bits: int = 8                   # efficient_adam
    onebit_warmup_rounds: int = 2
    q_bits: int = 32                      # accounting float precision
    client_mode: str = "scan"             # scan | vmap
    aggregate: str = "dense"              # dense | sparse_gather (vmap only)
    client_axes: Optional[Tuple[str, ...]] = None  # mesh axes of client dim
    use_kernel_adam: bool = False         # fused_adam Pallas kernel
    per_epoch_batches: bool = False       # batch has a leading L axis
    value_dtype: Optional[str] = None     # beyond-paper value transport cast
    # beyond-paper: partial participation — fraction of clients sampled per
    # round (the paper uses full participation, N=20).  Sampled by masking
    # FedAvg weights so compiled shapes stay static.
    participation: float = 1.0

    def __post_init__(self):
        assert self.algorithm in ALGORITHMS, self.algorithm


class FedState(NamedTuple):
    W: Any                                # global model
    M: Any                                # global first moments
    V: Any                                # global second moments
    round: jax.Array                      # int32 scalar
    client_state: Any                     # EF residuals etc. (may be None)


def fed_init(fed: FedConfig, params) -> FedState:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    client_state = None
    if fed.algorithm in ("onebit_adam", "efficient_adam") or fed.error_feedback:
        err = jax.tree.map(
            lambda x: jnp.zeros((fed.n_clients,) + x.shape, x.dtype), params)
        client_state = {"err": err}
        if fed.algorithm == "efficient_adam":
            client_state["m"] = jax.tree.map(
                lambda x: jnp.zeros((fed.n_clients,) + x.shape, x.dtype), params)
            client_state["v"] = jax.tree.map(
                lambda x: jnp.zeros((fed.n_clients,) + x.shape, x.dtype), params)
    return FedState(W=params, M=zeros(), V=zeros(),
                    round=jnp.zeros((), jnp.int32), client_state=client_state)


# ---------------------------------------------------------------------------
# Local training
# ---------------------------------------------------------------------------


def _local_adam(loss_fn, W, M, V, batch, fed: FedConfig):
    """L local Adam epochs from the downloaded global state."""
    h = fed.adam
    state0 = AdamState(M, V, jnp.zeros((), jnp.int32))

    def epoch(carry, xs):
        w, st = carry
        b = xs if fed.per_epoch_batches else batch
        loss, g = jax.value_and_grad(loss_fn)(w, b)
        w, st = adam_step(w, g, st, h, use_kernel=fed.use_kernel_adam)
        return (w, st), loss

    if fed.per_epoch_batches:
        (w, st), losses = lax.scan(epoch, (W, state0), batch)
    else:
        (w, st), losses = lax.scan(epoch, (W, state0), None,
                                   length=fed.local_epochs)
    return w, st.m, st.v, jnp.mean(losses)


def _local_sgd(loss_fn, W, batch, fed: FedConfig):
    def epoch(w, xs):
        b = xs if fed.per_epoch_batches else batch
        loss, g = jax.value_and_grad(loss_fn)(w, b)
        w, _ = sgd_step(w, g, fed.adam.lr)
        return w, loss

    if fed.per_epoch_batches:
        w, losses = lax.scan(epoch, W, batch)
    else:
        w, losses = lax.scan(epoch, W, None, length=fed.local_epochs)
    return w, jnp.mean(losses)


# ---------------------------------------------------------------------------
# Per-client compression
# ---------------------------------------------------------------------------


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: (x.astype(_F32) - y.astype(_F32))
                        .astype(x.dtype), a, b)


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: (x.astype(_F32) + y.astype(_F32))
                        .astype(x.dtype), a, b)


def _cast_values(fed: FedConfig, tree):
    if fed.value_dtype is None:
        return tree
    dt = jnp.dtype(fed.value_dtype)
    return jax.tree.map(lambda x: x.astype(dt).astype(x.dtype), tree)


def _compress_sparse(fed: FedConfig, dW, dM, dV, err):
    """Shared-mask / independent-mask sparsification.  Returns
    (masked deltas, new_err, metrics)."""
    if err is not None:
        dW = _tree_add(dW, err)
    if fed.algorithm == "fedadam_top":
        mW, mM, mV = masks.independent_masks(
            dW, dM, dV, fed.alpha, fed.mask_scope, fed.exact_topk)
    else:
        rule = _RULE_OF[fed.algorithm]
        mW = masks.shared_mask(rule, dW, dM, dV, fed.alpha,
                               fed.mask_scope, fed.exact_topk)
        mM = mV = mW
    sW = S.tree_sparsify(dW, mW)
    sM = S.tree_sparsify(dM, mM)
    sV = S.tree_sparsify(dV, mV)
    sW, sM, sV = (_cast_values(fed, t) for t in (sW, sM, sV))
    new_err = _tree_sub(dW, sW) if err is not None else None
    metrics = {
        "err_w": S.tree_sparsity_error(dW, mW),
        "err_m": S.tree_sparsity_error(dM, mM),
        "err_v": S.tree_sparsity_error(dV, mV),
        "norm_dw": S.tree_norm(dW),
        "norm_dm": S.tree_norm(dM),
        "norm_dv": S.tree_norm(dV),
    }
    return (sW, sM, sV), new_err, metrics


def _zero_metrics():
    z = jnp.zeros((), _F32)
    return {k: z for k in ("err_w", "err_m", "err_v",
                           "norm_dw", "norm_dm", "norm_dv")}


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------


def make_fl_round(fed: FedConfig, loss_fn: Callable,
                  sparse_aggregate_fn: Optional[Callable] = None):
    """Build ``round_fn(state, batches, weights=None) -> (state, metrics)``.

    ``sparse_aggregate_fn(sW_c, sM_c, sV_c, weights) -> (aW, aM, aV)``:
    optional shard_map-based transport (core.aggregate.
    make_shardmap_sparse_aggregate) injected by the launcher; without it the
    pure-jnp gather/scatter path is used (CPU tests, small models).

    batches: pytree whose leaves have leading dims (C, [L,] ...) — client-
    major (and epoch-major when per_epoch_batches).  weights: optional (C,)
    FedAvg weights |D_n| (defaults to uniform).
    """

    def client_step(W, M, V, batch, cstate):
        """One client's round: local epochs + compression.
        Returns (sW, sM, sV, new_cstate, metrics)."""
        if fed.algorithm == "fedsgd":
            w, loss = _local_sgd(loss_fn, W, batch, fed)
            dW = _tree_sub(w, W)
            zeros = jax.tree.map(jnp.zeros_like, dW)
            return dW, zeros, zeros, cstate, dict(_zero_metrics(), loss=loss)

        if fed.algorithm == "onebit_adam":
            # one momentum step; V frozen after warmup (handled by caller
            # passing frozen V); communicate sign-quantized momentum delta.
            b = jax.tree.map(lambda x: x[0], batch) \
                if fed.per_epoch_batches else batch
            loss, g = jax.value_and_grad(loss_fn)(W, b)
            h = fed.adam
            m_new = jax.tree.map(
                lambda m, gg: (h.beta1 * m.astype(_F32)
                               + (1 - h.beta1) * gg.astype(_F32)).astype(m.dtype),
                M, g)
            dM = _tree_sub(m_new, M)
            err = cstate["err"]
            dM_c = _tree_add(dM, err)
            q = quantize.tree_sign_quant(dM_c)
            new_err = _tree_sub(dM_c, q)
            # W delta implied server-side: -lr * (M+q)/sqrt(V_frozen)
            zeros = jax.tree.map(jnp.zeros_like, q)
            return zeros, q, zeros, {"err": new_err}, \
                dict(_zero_metrics(), loss=loss)

        if fed.algorithm == "efficient_adam":
            # persistent local moments (never aggregated — the staleness
            # the paper criticizes); two-way b-bit quantization with EF.
            m0, v0 = cstate["m"], cstate["v"]
            w, m, v, loss = _local_adam(loss_fn, W, m0, v0, batch, fed)
            dW = _tree_sub(w, W)
            dW_c = _tree_add(dW, cstate["err"])
            q = quantize.tree_uniform_quant(dW_c, fed.quant_bits)
            new_err = _tree_sub(dW_c, q)
            zeros = jax.tree.map(jnp.zeros_like, q)
            return q, zeros, zeros, {"err": new_err, "m": m, "v": v}, \
                dict(_zero_metrics(), loss=loss)

        # Adam-family: fedadam (dense) and all sparse variants
        w, m, v, loss = _local_adam(loss_fn, W, M, V, batch, fed)
        dW, dM, dV = _tree_sub(w, W), _tree_sub(m, M), _tree_sub(v, V)
        if fed.algorithm == "fedadam":
            mets = dict(_zero_metrics(), loss=loss,
                        norm_dw=S.tree_norm(dW), norm_dm=S.tree_norm(dM),
                        norm_dv=S.tree_norm(dV))
            return dW, dM, dV, cstate, mets
        err = cstate["err"] if (cstate is not None and fed.error_feedback) \
            else None
        (sW, sM, sV), new_err, mets = _compress_sparse(fed, dW, dM, dV, err)
        new_cstate = {"err": new_err} if new_err is not None else cstate
        return sW, sM, sV, new_cstate, dict(mets, loss=loss)

    # -- round drivers --------------------------------------------------

    def round_scan(state: FedState, batches, weights):
        W, M, V = state.W, state.M, state.V
        zero = lambda: jax.tree.map(
            lambda x: jnp.zeros(x.shape, _F32), W)
        acc0 = (zero(), zero(), zero())

        cs = state.client_state
        cs_stub = jax.tree.map(lambda x: x[0], cs) if cs is not None else None

        has_cs = cs is not None

        def body(carry, xs):
            (aW, aM, aV), wsum = carry
            if has_cs:
                batch, wgt, cstate = xs
            else:
                batch, wgt = xs
                cstate = None
            sW, sM, sV, ncs, mets = client_step(W, M, V, batch, cstate)
            add = lambda a, s: jax.tree.map(
                lambda x, y: x + wgt * y.astype(_F32), a, s)
            ys = (ncs, mets) if has_cs else (0.0, mets)
            return ((add(aW, sW), add(aM, sM), add(aV, sV)), wsum + wgt), ys

        xs = (batches, weights, cs) if has_cs else (batches, weights)
        ((aW, aM, aV), wsum), (new_cs, mets) = lax.scan(body, (acc0, 0.0), xs)
        return (aW, aM, aV), wsum, (new_cs if has_cs else None), mets

    def round_shardmap(state: FedState, batches, weights):
        """Spatial clients, production path: the per-client local-training
        region runs under shard_map MANUAL over the client mesh axes (auto
        over "model"), so divergent client replicas are structurally
        per-device — GSPMD cannot replicate them (the pure-vmap formulation
        showed 10-100x memory blow-ups at scale).  Aggregation then runs in
        the global view (dense) or via the injected shard_map transport."""
        from jax import shard_map

        W, M, V = state.W, state.M, state.V
        caxes = tuple(fed.client_axes)
        cax = caxes if len(caxes) > 1 else caxes[0]

        def body(Wb, Mb, Vb, batch, wts):
            batch_l = jax.tree.map(lambda x: x[0], batch)
            sW, sM, sV, _, mets = client_step(Wb, Mb, Vb, batch_l, None)
            lead = lambda t: jax.tree.map(lambda x: x[None], t)
            mets = jax.tree.map(lambda x: x[None], mets)
            return lead(sW), lead(sM), lead(sV), mets

        rep = lambda tree: jax.tree.map(lambda _: PartitionSpec(), tree)
        stk = lambda tree: jax.tree.map(
            lambda x: PartitionSpec(cax, *([None] * (x.ndim - 1))), tree)
        mets_spec = {k: PartitionSpec(cax)
                     for k in list(_zero_metrics()) + ["loss"]}
        sW, sM, sV, mets = shard_map(
            body,
            in_specs=(rep(W), rep(M), rep(V), stk(batches),
                      PartitionSpec(None)),
            out_specs=(stk(W), stk(W), stk(W), mets_spec),
            axis_names=frozenset(caxes),
            check_vma=False,
        )(W, M, V, batches, weights)

        wsum = jnp.sum(weights.astype(_F32))
        if fed.aggregate == "sparse_gather" and sparse_aggregate_fn is not None:
            aW, aM, aV = sparse_aggregate_fn(sW, sM, sV, weights)
        else:
            aW = aggregate.dense_weighted_sum(sW, weights)
            aM = aggregate.dense_weighted_sum(sM, weights)
            aV = aggregate.dense_weighted_sum(sV, weights)
        return (aW, aM, aV), wsum, None, mets

    def round_vmap(state: FedState, batches, weights):
        W, M, V = state.W, state.M, state.V
        cs = state.client_state

        def one(batch, cstate):
            return client_step(W, M, V, batch, cstate)

        in_axes = (0, 0 if cs is not None else None)
        sW, sM, sV, new_cs, mets = jax.vmap(one, in_axes=in_axes)(batches, cs)
        # pin the per-client delta stacks to the client mesh axes — without
        # this GSPMD may replicate the divergent client states (C x params
        # per device) through the vmapped local-training region
        if fed.client_axes:
            def pin(tree):
                def one_leaf(x):
                    spec = PartitionSpec(
                        tuple(fed.client_axes) if len(fed.client_axes) > 1
                        else fed.client_axes[0],
                        *([None] * (x.ndim - 1)))
                    return lax.with_sharding_constraint(x, spec)
                return jax.tree.map(one_leaf, tree)
            sW, sM, sV = pin(sW), pin(sM), pin(sV)
        wsum = jnp.sum(weights.astype(_F32))
        if fed.aggregate == "sparse_gather" and sparse_aggregate_fn is not None:
            aW, aM, aV = sparse_aggregate_fn(sW, sM, sV, weights)
        elif fed.aggregate == "sparse_gather" and \
                fed.algorithm in _RULE_OF:           # shared-mask family
            aW, aM, aV = aggregate.sparse_shared_gather_sum(
                sW, sM, sV, fed.alpha, weights, fed.value_dtype,
                sort_free=not fed.exact_topk)
        elif fed.aggregate == "sparse_gather" and \
                fed.algorithm == "fedadam_top":
            agg = lambda t: aggregate.sparse_independent_gather_sum(
                t, fed.alpha, weights, fed.value_dtype,
                sort_free=not fed.exact_topk)
            aW, aM, aV = agg(sW), agg(sM), agg(sV)
        else:
            aW = aggregate.dense_weighted_sum(sW, weights)
            aM = aggregate.dense_weighted_sum(sM, weights)
            aV = aggregate.dense_weighted_sum(sV, weights)
        return (aW, aM, aV), wsum, \
            (new_cs if cs is not None else None), mets

    def round_fn(state: FedState, batches, weights=None, rng=None):
        C = fed.n_clients
        if weights is None:
            weights = jnp.ones((C,), _F32)
        if fed.participation < 1.0:
            # sample ceil(p*C) clients by weight masking (static shapes);
            # rng defaults to the round counter for reproducibility
            m = max(1, int(round(fed.participation * C)))
            key = rng if rng is not None else \
                jax.random.fold_in(jax.random.PRNGKey(17), state.round)
            perm = jax.random.permutation(key, C)
            active = jnp.zeros((C,), _F32).at[perm[:m]].set(1.0)
            weights = weights * active
        if fed.client_mode == "scan":
            driver = round_scan
        elif fed.client_axes is not None:
            driver = round_shardmap
        else:
            driver = round_vmap
        (aW, aM, aV), wsum, new_cs, mets = driver(state, batches, weights)
        mean = lambda t: jax.tree.map(lambda x: x / wsum, t)
        aW, aM, aV = mean(aW), mean(aM), mean(aV)

        h = fed.adam
        if fed.algorithm == "onebit_adam":
            warm = state.round < fed.onebit_warmup_rounds
            # warmup: clients behaved like fedadam?  (caller uses a separate
            # dense FedConfig during warmup; here we always apply the
            # compressed path:)  M advances by the aggregated momentum
            # delta; W by the preconditioned step with frozen V.
            M_new = _tree_add(state.M, aM)
            upd = jax.tree.map(
                lambda mm, vv: (h.lr * mm.astype(_F32)
                                / jnp.sqrt(vv.astype(_F32) + h.eps)),
                M_new, state.V)
            W_new = jax.tree.map(
                lambda w, u: (w.astype(_F32) - u).astype(w.dtype),
                state.W, upd)
            V_new = state.V
        elif fed.algorithm == "efficient_adam":
            W_new = _tree_add(state.W, aW)
            M_new, V_new = state.M, state.V
        elif fed.algorithm == "fedsgd":
            W_new = _tree_add(state.W, aW)
            M_new, V_new = state.M, state.V
        else:
            W_new = _tree_add(state.W, aW)
            M_new = _tree_add(state.M, aM)
            V_new = _tree_add(state.V, aV)

        # uplink accounting (exact bits, Section IV / VII formulas)
        d = sum(x.size for x in jax.tree.leaves(state.W))
        k = S.k_for(d, fed.alpha)
        mets = dict(mets)
        active_clients = (max(1, int(round(fed.participation * C)))
                          if fed.participation < 1.0 else C)
        mets["uplink_bits"] = jnp.asarray(
            comm.bits_for(fed.algorithm, d, k, active_clients, fed.q_bits,
                          quant_bits=fed.quant_bits), _F32)
        new_state = FedState(W=W_new, M=M_new, V=V_new,
                             round=state.round + 1, client_state=new_cs)
        return new_state, mets

    return round_fn
