"""FedAdam-SSM and baselines — Algorithms 1 & 2 of the paper.

One FL round (Algorithm 2):

1. every client starts local state from the global (W^t, M^t, V^t);
2. L local Adam epochs (Eqs. 3-5; no bias correction) on the client's data;
3. client deltas  dW = w - W^t, dM = m - M^t, dV = v - V^t;
4. compression:   the round's ``Compressor`` (core/compressors registry,
   selected by ``FedConfig.algorithm``) encodes the delta triple — the
   paper's SHARED sparse mask (Eq. 28: mask = Top_k(|dW|)) for
   FedAdam-SSM, or the per-algorithm alternative — carrying any
   per-client error-feedback state across rounds;
5. server FedAvg over the compressed deltas; globals advance by the
   aggregate per the compressor's ``server_update`` rule.

The paper's Algorithm 2 downloads the *previous* round's aggregate at the
start of the next round; applying the aggregate at the end of the current
round is the same sequence of states (the lag is only a pipelining detail),
which is how we implement it.

The round function is architecture-agnostic: it sees an abstract
``loss_fn(params, batch) -> scalar`` and parameter pytrees, so every
architecture in the zoo trains with the technique unchanged.  It is also
algorithm-agnostic: all per-scheme behaviour (what is communicated, the
error-feedback semantics, the uplink bit accounting, which aggregation
transport applies) lives behind the compressor's declarative tags —
adding a scheme is a compressor registration, not a surgery here.  See
docs/compressors.md.

Client execution modes
----------------------
* ``scan``  — virtual clients: sequential ``lax.scan`` over the client axis
  (memory = one client); the mesh parallelizes *within* a client.
* ``vmap``  — spatial clients: the leading client axis of the batch is
  sharded over mesh axes ("data"/"pod"); per-client local training runs
  under ``vmap`` so divergent client replicas coexist, and the aggregation
  reduce IS the uplink collective (see core/aggregate.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import aggregate, compressors
from repro.core.compressors import DIAG_KEYS, Deltas
from repro.core.compressors.base import tree_add as _tree_add
from repro.core.compressors.base import tree_sub as _tree_sub
from repro.optim.adam import AdamHyper, AdamState, adam_step, sgd_step

_F32 = jnp.float32

#: Built-in algorithm names, in canonical order (== the compressor
#: registry's registration order; see core/compressors/__init__.py):
#:
#: fedadam_ssm    — the paper's contribution (shared mask rule ssm_w)
#: ssm_m, ssm_v   — baselines: shared mask from |dM| / |dV|
#: fairness_top   — baseline: shared mask from the normalized union
#: fedadam_top    — baseline: three independent top-k masks
#: fedadam        — baseline: dense FedAdam (alpha=1 special case)
#: fedsgd         — baseline: dense FedSGD
#: onebit_adam    — baseline: 1-bit Adam (warmup + frozen precondition)
#: efficient_adam — baseline: two-way quantized Adam with EF
ALGORITHMS = compressors.available()


@dataclasses.dataclass(frozen=True)
class FedConfig:
    algorithm: str = "fedadam_ssm"
    alpha: float = 0.05                   # sparsification ratio k/d
    local_epochs: int = 30
    n_clients: int = 20
    adam: AdamHyper = AdamHyper()
    mask_scope: str = "per_tensor"        # per_tensor | global
    exact_topk: bool = True               # exact sort vs threshold bisection
    # auto | kernel | reference — which sparsifier implementation the
    # threshold masks use (core/sparsify.resolve_backend: auto routes TPU
    # to the Pallas kernels; REPRO_SPARSIFY_BACKEND env overrides)
    sparsify_backend: str = "auto"
    error_feedback: bool = False          # beyond-paper for sparse algos
    quant_bits: int = 8                   # efficient_adam
    onebit_warmup_rounds: int = 2
    q_bits: int = 32                      # accounting float precision
    client_mode: str = "scan"             # scan | vmap
    aggregate: str = "dense"              # dense | sparse_gather (vmap only)
    client_axes: Optional[Tuple[str, ...]] = None  # mesh axes of client dim
    use_kernel_adam: bool = False         # fused_adam Pallas kernel
    per_epoch_batches: bool = False       # batch has a leading L axis
    value_dtype: Optional[str] = None     # beyond-paper value transport cast
    # beyond-paper: partial participation — fraction of clients sampled per
    # round (the paper uses full participation, N=20).  Sampled by masking
    # FedAvg weights so compiled shapes stay static.
    participation: float = 1.0

    def __post_init__(self):
        # any *registered* compressor is a valid algorithm — drop-in
        # schemes registered via compressors.register() pass too
        assert self.algorithm in compressors.available(), self.algorithm


def active_client_count(fed: FedConfig) -> int:
    """Clients sampled per round: ``round(participation * n_clients)``,
    never below one.  THE single site where the participation fraction
    meets host ``int()`` math — it runs at round-*build* time and its
    value is closed over by the jitted round body, so the cast can never
    see a tracer (the jit-hazard lint rule guards the round body).

    Invariant (relied on by every participation consumer):

    * host-static ``int`` in ``[1, n_clients]`` — banker's rounding via
      Python ``round`` (``participation=0.5, n_clients=5`` -> 2), and
      ``participation=0.0`` still yields 1 (a round with zero clients
      is never built);
    * the SAME count drives both participation realizations: the sync
      round samples exactly this many clients by *weight masking* (the
      ``round_fn`` permutation below — compiled shapes stay static, an
      inactive client contributes weight 0.0 and its bits are not
      accounted), and the buffered-async driver
      (:mod:`repro.core.async_fed`) restricts its *dispatch pool* to
      this many clients, so sync and async agree on how many clients a
      given ``participation`` admits.

    Boundary behaviour is pinned by ``tests/test_fed.py::
    test_active_client_count_boundaries``.
    """
    return max(1, int(round(fed.participation * fed.n_clients)))


class FedState(NamedTuple):
    W: Any                                # global model
    M: Any                                # global first moments
    V: Any                                # global second moments
    round: jax.Array                      # int32 scalar
    client_state: Any                     # per-client state (may be None):
    #   {"comp": <compressor EF state>, "m"/"v": persistent local moments}


def fed_init(fed: FedConfig, params) -> FedState:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    comp = compressors.make_compressor(fed)
    C = fed.n_clients
    stack0 = lambda t: jax.tree.map(
        lambda x: jnp.zeros((C,) + x.shape, x.dtype), t)
    parts = {}
    cs1 = comp.init_state(params)
    if cs1 is not None:
        # replicate the single-client compressor state over the client axis
        parts["comp"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), cs1)
    if comp.local_update == "local_adam":
        # persistent local Adam moments (efficient_adam: never aggregated)
        parts["m"] = stack0(params)
        parts["v"] = stack0(params)
    return FedState(W=params, M=zeros(), V=zeros(),
                    round=jnp.zeros((), jnp.int32),
                    client_state=parts or None)


def client_state_pspecs(client_state, param_pspecs, client_axes):
    """PartitionSpec pytree for a client-stacked ``client_state`` tree.

    Every leaf gets its leading client axis placed on ``client_axes``
    (``None`` for the scan driver's virtual-client axis, which no mesh
    axis carries).  Trailing dims follow the *param* sharding whenever a
    sub-tree mirrors the params treedef — which is exactly how fed_init
    builds the EF residuals (``{"comp": {"err": params-like}}``) and the
    ``local_adam`` moments (``"m"``/``"v"``) — so at the jit boundary a
    client's residual shard is laid out like its param shard, not
    replicated across the model axes.  Unrecognized sub-trees (custom
    compressor state) fall back to client-axis-only placement.
    """
    if client_state is None:
        return None
    cax = (tuple(client_axes) if len(client_axes) > 1 else client_axes[0]) \
        if client_axes else None
    pleaves, ptreedef = jax.tree_util.tree_flatten(
        param_pspecs, is_leaf=lambda x: isinstance(x, PartitionSpec))

    def spec_for(sub):
        try:
            ptreedef.flatten_up_to(sub)
        except (ValueError, TypeError):
            if isinstance(sub, dict):
                return {k: spec_for(v) for k, v in sub.items()}
            return jax.tree.map(
                lambda x: PartitionSpec(cax, *([None] * (x.ndim - 1))), sub)
        return ptreedef.unflatten(
            [PartitionSpec(cax, *sp) for sp in pleaves])

    return spec_for(client_state)


# ---------------------------------------------------------------------------
# Local training
# ---------------------------------------------------------------------------


def _local_adam(loss_fn, W, M, V, batch, fed: FedConfig):
    """L local Adam epochs from the downloaded global state."""
    h = fed.adam
    state0 = AdamState(M, V, jnp.zeros((), jnp.int32))

    def epoch(carry, xs):
        w, st = carry
        b = xs if fed.per_epoch_batches else batch
        loss, g = jax.value_and_grad(loss_fn)(w, b)
        w, st = adam_step(w, g, st, h, use_kernel=fed.use_kernel_adam)
        return (w, st), loss

    if fed.per_epoch_batches:
        (w, st), losses = lax.scan(epoch, (W, state0), batch)
    else:
        (w, st), losses = lax.scan(epoch, (W, state0), None,
                                   length=fed.local_epochs)
    return w, st.m, st.v, jnp.mean(losses)


def _local_sgd(loss_fn, W, batch, fed: FedConfig):
    def epoch(w, xs):
        b = xs if fed.per_epoch_batches else batch
        loss, g = jax.value_and_grad(loss_fn)(w, b)
        w, _ = sgd_step(w, g, fed.adam.lr)
        return w, loss

    if fed.per_epoch_batches:
        w, losses = lax.scan(epoch, W, batch)
    else:
        w, losses = lax.scan(epoch, W, None, length=fed.local_epochs)
    return w, jnp.mean(losses)


def _local_momentum(loss_fn, W, M, batch, fed: FedConfig):
    """One momentum step (1-bit Adam compressed phase: V frozen)."""
    b = jax.tree.map(lambda x: x[0], batch) \
        if fed.per_epoch_batches else batch
    loss, g = jax.value_and_grad(loss_fn)(W, b)
    h = fed.adam
    m_new = jax.tree.map(
        lambda m, gg: (h.beta1 * m.astype(_F32)
                       + (1 - h.beta1) * gg.astype(_F32)).astype(m.dtype),
        M, g)
    return m_new, loss


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------


def make_client_step(fed: FedConfig, loss_fn: Callable,
                     comp: Optional[compressors.Compressor] = None,
                     *, emit: str = "dense", wire_roundtrip: bool = True):
    """Build ONE client's round: local epochs + compression.

    ``client_step(W, M, V, batch, cstate) ->
    (sW, sM, sV, new_cstate, metrics)`` — the per-client unit of work
    every driver shares: ``make_fl_round``'s scan/vmap/shard_map bodies
    run it over the cohort, and the buffered-async driver
    (:mod:`repro.core.async_fed`) runs it per dispatch against a stale
    parameter snapshot.  Keeping this a single builder is what makes
    sync <-> async degenerate-config equivalence *bitwise* rather than
    approximate (tests/test_async_fed.py).

    The carriers the step hands back are the WIRE-decoded ones whenever
    the compressor built a bit-packed payload (core/wire.py): the server
    sees exactly what survives the transported bytes, not the encoder's
    dense scratch.  For mask schemes the two are bit-identical; for
    quantized schemes they agree to the codec's round-trip (exact here:
    codes+scales reproduce the dense carrier bitwise).  Dense transport
    skips the round-trip — it is the identity, and FedSGD's identity
    carriers ship W only.

    ``wire_roundtrip=False`` keeps the dense-carrier output (the
    round-trip being bitwise, numerics are unchanged) WITHOUT touching
    the packed cohort buffer.  The mesh driver needs this: inside its
    shard_map region the leaves are model-sharded, and the wire pack's
    ravel/concatenate would force weight all-gathers in the global view
    — the transport realization there is the per-shard bitmap path in
    ``aggregate.make_shardmap_sparse_aggregate`` instead.

    ``emit="wire"`` (the vmap sparse-gather transport) returns
    ``(payload, new_cstate, metrics)`` instead — the bit-packed
    :class:`~repro.core.wire.WirePayload` IS the client's output, so the
    driver can move only packed words across the client axis and decode
    server-side.  Only valid when the compressor has a wire realization
    for this config."""
    if comp is None:
        comp = compressors.make_compressor(fed)
    assert emit in ("dense", "wire"), emit

    def client_step(W, M, V, batch, cstate):
        comp_state = cstate.get("comp") if cstate is not None else None
        extras = {}

        if comp.local_update == "sgd":
            w, loss = _local_sgd(loss_fn, W, batch, fed)
            dW = _tree_sub(w, W)
            z = jax.tree.map(jnp.zeros_like, dW)
            deltas = Deltas(dW, z, z)
        elif comp.local_update == "momentum":
            m_new, loss = _local_momentum(loss_fn, W, M, batch, fed)
            dM = _tree_sub(m_new, M)
            z = jax.tree.map(jnp.zeros_like, dM)
            deltas = Deltas(z, dM, z)
        elif comp.local_update == "local_adam":
            # persistent local moments (never aggregated — the staleness
            # the paper criticizes)
            w, m, v, loss = _local_adam(loss_fn, W, cstate["m"],
                                        cstate["v"], batch, fed)
            dW = _tree_sub(w, W)
            z = jax.tree.map(jnp.zeros_like, dW)
            deltas = Deltas(dW, z, z)
            extras = {"m": m, "v": v}
        else:                             # "adam": the FedAdam family
            w, m, v, loss = _local_adam(loss_fn, W, M, V, batch, fed)
            deltas = Deltas(_tree_sub(w, W), _tree_sub(m, M),
                            _tree_sub(v, V))

        packed, new_comp_state, _bits = comp.compress(deltas, comp_state)
        if cstate is None:
            new_cstate = None
        else:
            new_cstate = dict(cstate)
            if "comp" in cstate:
                new_cstate["comp"] = new_comp_state
            new_cstate.update(extras)
        mets = dict(packed.diag, loss=loss)
        if emit == "wire":
            assert packed.wire is not None, \
                f"{comp.name}: emit='wire' but compress built no payload"
            return packed.wire, new_cstate, mets
        if wire_roundtrip and packed.wire is not None \
                and comp.transport != "dense":
            sW, sM, sV = comp.unpack_wire(packed.wire, deltas.W)
        else:
            sW, sM, sV = comp.decompress(packed)
        return sW, sM, sV, new_cstate, mets

    return client_step


def make_server_apply(fed: FedConfig,
                      comp: Optional[compressors.Compressor] = None):
    """Build the server-side tail of a round: FedAvg mean + the
    compressor's declarative ``server_update`` rule.

    ``server_apply(W, M, V, aW, aM, aV, wsum) -> (W', M', V')`` where
    ``(aW, aM, aV)`` are weighted SUMS over whatever cohort delivered
    (full cohort in the sync round, the K-deep buffer in the async
    driver) and ``wsum`` the matching weight total.  Shared verbatim by
    ``make_fl_round`` and :mod:`repro.core.async_fed`, so the two
    drivers can never disagree on the server arithmetic."""
    if comp is None:
        comp = compressors.make_compressor(fed)
    h = fed.adam

    def server_apply(W, M, V, aW, aM, aV, wsum):
        mean = lambda t: jax.tree.map(lambda x: x / wsum, t)
        aW, aM, aV = mean(aW), mean(aM), mean(aV)
        if comp.server_update == "precond_m":
            # 1-bit Adam: M advances by the aggregated momentum delta; W
            # by the preconditioned step with frozen V.  (Warmup rounds
            # run as a separate dense FedConfig — see the two-phase
            # protocol in tests/test_fed.py.)
            M_new = _tree_add(M, aM)
            upd = jax.tree.map(
                lambda mm, vv: (h.lr * mm.astype(_F32)
                                / jnp.sqrt(vv.astype(_F32) + h.eps)),
                M_new, V)
            W_new = jax.tree.map(
                lambda w, u: (w.astype(_F32) - u).astype(w.dtype),
                W, upd)
            V_new = V
        elif comp.server_update == "w_only":
            W_new = _tree_add(W, aW)
            M_new, V_new = M, V
        else:                             # "wmv": the FedAdam family
            W_new = _tree_add(W, aW)
            M_new = _tree_add(M, aM)
            V_new = _tree_add(V, aV)
        return W_new, M_new, V_new

    return server_apply


def make_fl_round(fed: FedConfig, loss_fn: Callable,
                  sparse_aggregate_fn: Optional[Callable] = None):
    """Build ``round_fn(state, batches, weights=None) -> (state, metrics)``.

    ``sparse_aggregate_fn(sW_c, sM_c, sV_c, weights) -> (aW, aM, aV)``:
    optional shard_map-based transport (core.aggregate.
    make_shardmap_sparse_aggregate) injected by the launcher; without it the
    pure-jnp gather/scatter path is used (CPU tests, small models).

    batches: pytree whose leaves have leading dims (C, [L,] ...) — client-
    major (and epoch-major when per_epoch_batches).  weights: optional (C,)
    FedAvg weights |D_n| (defaults to uniform).
    """
    comp = compressors.make_compressor(fed)
    n_active = active_client_count(fed)
    client_step = make_client_step(fed, loss_fn, comp)
    # the mesh driver's step skips the (bitwise-identity) wire round-trip:
    # packing model-sharded leaves in the global view would all-gather
    # the weights; its transport is the per-shard bitmap aggregate
    mesh_client_step = make_client_step(fed, loss_fn, comp,
                                        wire_roundtrip=False)
    server_apply = make_server_apply(fed, comp)

    # -- round drivers --------------------------------------------------

    def round_scan(state: FedState, batches, weights):
        W, M, V = state.W, state.M, state.V
        zero = lambda: jax.tree.map(
            lambda x: jnp.zeros(x.shape, _F32), W)
        acc0 = (zero(), zero(), zero())

        cs = state.client_state
        has_cs = cs is not None

        def body(carry, xs):
            (aW, aM, aV), wsum = carry
            if has_cs:
                batch, wgt, cstate = xs
            else:
                batch, wgt = xs
                cstate = None
            sW, sM, sV, ncs, mets = client_step(W, M, V, batch, cstate)
            add = lambda a, s: jax.tree.map(
                lambda x, y: x + wgt * y.astype(_F32), a, s)
            ys = (ncs, mets) if has_cs else (0.0, mets)
            return ((add(aW, sW), add(aM, sM), add(aV, sV)), wsum + wgt), ys

        xs = (batches, weights, cs) if has_cs else (batches, weights)
        ((aW, aM, aV), wsum), (new_cs, mets) = lax.scan(body, (acc0, 0.0), xs)
        return (aW, aM, aV), wsum, (new_cs if has_cs else None), mets

    def round_shardmap(state: FedState, batches, weights):
        """Spatial clients, production path: the per-client local-training
        region runs under shard_map MANUAL over the client mesh axes (auto
        over "model"), so divergent client replicas are structurally
        per-device — GSPMD cannot replicate them (the pure-vmap formulation
        showed 10-100x memory blow-ups at scale).  Per-client compressor
        state (EF residuals under ``client_state["comp"]``, plus the
        ``local_adam`` persistent moments) enters the MANUAL region sharded
        over the same client axes, is consumed/produced by ``client_step``
        exactly as under scan/vmap, and leaves the region still sharded —
        it never materializes unsharded.  Aggregation then runs in the
        global view (dense) or via the injected shard_map transport."""
        from repro.compat import shard_map

        W, M, V = state.W, state.M, state.V
        cs = state.client_state
        has_cs = cs is not None
        caxes = tuple(fed.client_axes)
        cax = caxes if len(caxes) > 1 else caxes[0]

        def body(Wb, Mb, Vb, batch, wts, cstate):
            batch_l = jax.tree.map(lambda x: x[0], batch)
            # one spatial client per device row: peel the client axis off
            # the state shard, thread it through the step, put it back
            cstate_l = jax.tree.map(lambda x: x[0], cstate)
            sW, sM, sV, ncs, mets = mesh_client_step(Wb, Mb, Vb, batch_l,
                                                     cstate_l)
            lead = lambda t: jax.tree.map(lambda x: x[None], t)
            mets = jax.tree.map(lambda x: x[None], mets)
            return lead(sW), lead(sM), lead(sV), lead(ncs), mets

        rep = lambda tree: jax.tree.map(lambda _: PartitionSpec(), tree)
        stk = lambda tree: jax.tree.map(
            lambda x: PartitionSpec(cax, *([None] * (x.ndim - 1))), tree)
        mets_spec = {k: PartitionSpec(cax)
                     for k in list(DIAG_KEYS) + ["loss"]}
        # cs=None is an empty pytree: its spec entry is None and the body's
        # tree.maps over it are no-ops, so the stateless path is unchanged
        sW, sM, sV, new_cs, mets = shard_map(
            body,
            in_specs=(rep(W), rep(M), rep(V), stk(batches),
                      PartitionSpec(None), stk(cs)),
            out_specs=(stk(W), stk(W), stk(W), stk(cs), mets_spec),
            axis_names=frozenset(caxes),
            check_vma=False,
        )(W, M, V, batches, weights, cs)

        wsum = jnp.sum(weights.astype(_F32))
        if fed.aggregate == "sparse_gather" and sparse_aggregate_fn is not None:
            # EF compressors: hand the transport the per-shard residuals so
            # values dropped by the pack's fixed capacity feed back into
            # next round's input instead of vanishing on the wire
            comp_err = new_cs["comp"].get("err") \
                if has_cs and isinstance(new_cs.get("comp"), dict) else None
            if comp_err is not None and comp.transport in (
                    "shared_sparse", "independent_sparse"):
                (aW, aM, aV), new_err = sparse_aggregate_fn(
                    sW, sM, sV, weights, comp_err)
                new_cs = dict(new_cs, comp=dict(new_cs["comp"],
                                                err=new_err))
            else:
                aW, aM, aV = sparse_aggregate_fn(sW, sM, sV, weights)
        else:
            # ordered (scan-identical) accumulation: the dense branch of
            # the mesh driver is the reference/debug path — bit-identical
            # to round_scan by construction (tests/test_fed_equivalence)
            aW = aggregate.ordered_weighted_sum(sW, weights)
            aM = aggregate.ordered_weighted_sum(sM, weights)
            aV = aggregate.ordered_weighted_sum(sV, weights)
        return (aW, aM, aV), wsum, (new_cs if has_cs else None), mets

    def round_vmap(state: FedState, batches, weights):
        W, M, V = state.W, state.M, state.V
        cs = state.client_state

        def pin(tree):
            if not fed.client_axes:
                return tree

            def one_leaf(x):
                spec = PartitionSpec(
                    tuple(fed.client_axes) if len(fed.client_axes) > 1
                    else fed.client_axes[0],
                    *([None] * (x.ndim - 1)))
                return lax.with_sharding_constraint(x, spec)
            return jax.tree.map(one_leaf, tree)

        in_axes = (0, 0 if cs is not None else None)
        wsum = jnp.sum(weights.astype(_F32))

        sizes = tuple(x.size for x in jax.tree.leaves(W))
        use_wire = (fed.aggregate == "sparse_gather"
                    and sparse_aggregate_fn is None
                    and comp.transport != "dense"
                    and comp.wire_bits_per_client(sizes) is not None)
        if use_wire:
            # wire transport: each vmapped client emits its bit-packed
            # WirePayload; ONLY the packed words + compact value/scale
            # streams cross the client axis, and the server decodes in
            # client order — the ordered fold is bitwise round_scan's
            wire_step = make_client_step(fed, loss_fn, comp, emit="wire")

            def one_wire(batch, cstate):
                return wire_step(W, M, V, batch, cstate)

            payload, new_cs, mets = jax.vmap(
                one_wire, in_axes=in_axes)(batches, cs)
            payload = pin(payload)
            aW, aM, aV = aggregate.packed_gather_sum(
                comp, None, None, None, weights, alpha=fed.alpha,
                value_dtype=fed.value_dtype, sort_free=not fed.exact_topk,
                payload_c=payload, like=W)
            return (aW, aM, aV), wsum, \
                (new_cs if cs is not None else None), mets

        def one(batch, cstate):
            return client_step(W, M, V, batch, cstate)

        sW, sM, sV, new_cs, mets = jax.vmap(one, in_axes=in_axes)(batches, cs)
        # pin the per-client delta stacks to the client mesh axes — without
        # this GSPMD may replicate the divergent client states (C x params
        # per device) through the vmapped local-training region
        sW, sM, sV = pin(sW), pin(sM), pin(sV)
        if fed.aggregate == "sparse_gather" and sparse_aggregate_fn is not None:
            aW, aM, aV = sparse_aggregate_fn(sW, sM, sV, weights)
        elif fed.aggregate == "sparse_gather":
            # transport keyed on the compressor — any shared_sparse /
            # independent_sparse compressor rides the packed all-gather
            aW, aM, aV = aggregate.packed_gather_sum(
                comp, sW, sM, sV, weights, alpha=fed.alpha,
                value_dtype=fed.value_dtype, sort_free=not fed.exact_topk)
        else:
            aW = aggregate.dense_weighted_sum(sW, weights)
            aM = aggregate.dense_weighted_sum(sM, weights)
            aV = aggregate.dense_weighted_sum(sV, weights)
        return (aW, aM, aV), wsum, \
            (new_cs if cs is not None else None), mets

    def round_fn(state: FedState, batches, weights=None, rng=None):
        C = fed.n_clients
        if weights is None:
            weights = jnp.ones((C,), _F32)
        if fed.participation < 1.0:
            # sample the active_client_count clients by weight masking
            # (static shapes); rng defaults to the round counter for
            # reproducibility
            key = rng if rng is not None else \
                jax.random.fold_in(jax.random.PRNGKey(17), state.round)
            perm = jax.random.permutation(key, C)
            active = jnp.zeros((C,), _F32).at[perm[:n_active]].set(1.0)
            weights = weights * active
        if fed.client_mode == "scan":
            driver = round_scan
        elif fed.client_axes is not None:
            driver = round_shardmap
        else:
            driver = round_vmap
        (aW, aM, aV), wsum, new_cs, mets = driver(state, batches, weights)
        W_new, M_new, V_new = server_apply(state.W, state.M, state.V,
                                           aW, aM, aV, wsum)

        # uplink accounting x participating clients — the metric is
        # produced by the same object that produced the payload.  When
        # the compressor ships a wire payload, report the MEASURED bytes
        # (8 * WirePayload.nbytes, core/wire.py — padding and capacity
        # slack included); only configs with no wire realization fall
        # back to the paper-analytic Section IV/VII count.
        d = sum(x.size for x in jax.tree.leaves(state.W))
        sizes = tuple(x.size for x in jax.tree.leaves(state.W))
        per_client = comp.wire_bits_per_client(sizes)
        if per_client is None:
            per_client = comp.bits_per_client(d)
        mets = dict(mets)
        mets["uplink_bits"] = jnp.asarray(n_active * per_client, _F32)
        new_state = FedState(W=W_new, M=M_new, V=V_new,
                             round=state.round + 1, client_state=new_cs)
        return new_state, mets

    return round_fn
