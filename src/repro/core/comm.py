"""Uplink/downlink communication accounting (bits) — Section IV & VII.

The paper counts, per communication round, with q = float precision bits,
d = model dimension, k = alpha*d, N = #devices:

* FedAdam        : 3 N d q
* FedAdam-Top    : min{ 3N(kq + d),  3Nk(q + log2 d) }      (mask vs index)
* FedAdam-SSM    : min{ N(3kq + d),  Nk(3q + log2 d) }      (one mask/index)
* 1-bit Adam     : warm-up rounds 3Ndq; compressed rounds N(d + q*d/B)
                   (sign bits + one scale per block of B)
* Efficient-Adam : N(b*d + q*d/B) for b-bit two-way quantization

These are *accounting* functions (exact bit counts reported as metrics);
the on-mesh collective realization lives in core/aggregate.py.  The FL
round does NOT call :func:`bits_for` directly: each compressor in
core/compressors reports its own per-client bits through these formulas
(``Compressor.bits_per_client``), so the metric is produced by the same
object that produced the payload and cannot drift from the transport.
Per-algorithm formula derivations: docs/compressors.md.
"""
from __future__ import annotations

import math
from typing import Sequence


def _ceil_log2(d: int) -> int:
    """ceil(log2 d) index-representation bits.  d <= 1 needs ZERO bits
    (a single-slot index set is fully determined) — the old ``max(2, d)``
    clamp silently billed 1 bit for degenerate 1-element test trees."""
    if d <= 1:
        return 0
    return math.ceil(math.log2(d))


def bits_fedadam(d: int, n_clients: int, q: int = 32) -> int:
    return 3 * n_clients * d * q


def bits_fedadam_top(d: int, k: int, n_clients: int, q: int = 32) -> int:
    mask_repr = 3 * n_clients * (k * q + d)
    index_repr = 3 * n_clients * k * (q + _ceil_log2(d))
    return int(min(mask_repr, index_repr))


def bits_fedadam_ssm(d: int, k: int, n_clients: int, q: int = 32) -> int:
    mask_repr = n_clients * (3 * k * q + d)
    index_repr = n_clients * k * (3 * q + _ceil_log2(d))
    return int(min(mask_repr, index_repr))


def bits_fedsgd(d: int, n_clients: int, q: int = 32) -> int:
    return n_clients * d * q


def bits_onebit_adam(d: int, n_clients: int, q: int = 32,
                     warmup: bool = False, block: int = 1024) -> int:
    if warmup:
        return bits_fedadam(d, n_clients, q)
    return n_clients * (d + q * math.ceil(d / block))


def bits_efficient_adam(d: int, n_clients: int, q: int = 32,
                        bits: int = 8, block: int = 1024) -> int:
    return n_clients * (bits * d + q * math.ceil(d / block))


def bits_for(algorithm: str, d: int, k: int, n_clients: int, q: int = 32,
             warmup: bool = False, quant_bits: int = 8, *,
             sizes: "Sequence[int] | None" = None,
             alpha: "float | None" = None,
             mask_scope: str = "per_tensor",
             exact_topk: bool = True) -> int:
    """Uplink bits for ``n_clients`` clients of algorithm ``algorithm``.

    Without ``sizes`` this is the paper-analytic Section IV/VII count
    (the formulas above).  With ``sizes`` (the model's per-leaf element
    counts) it is the WIRE-EXACT count: ``8 * WirePayload.nbytes`` of
    the payload the registered compressor actually ships, including
    layout padding and static mask-capacity slack (core/wire.py) —
    mask schemes then also need ``alpha``/``mask_scope``/``exact_topk``.
    """
    if sizes is not None:
        return n_clients * _wire_bits_one(
            algorithm, sizes, alpha, mask_scope, exact_topk,
            warmup=warmup, quant_bits=quant_bits, q=q)
    if algorithm in ("fedadam",):
        return bits_fedadam(d, n_clients, q)
    if algorithm in ("fedadam_top",):
        return bits_fedadam_top(d, k, n_clients, q)
    if algorithm in ("fedadam_ssm", "ssm_m", "ssm_v", "fairness_top"):
        return bits_fedadam_ssm(d, k, n_clients, q)
    if algorithm == "fedsgd":
        return bits_fedsgd(d, n_clients, q)
    if algorithm == "onebit_adam":
        return bits_onebit_adam(d, n_clients, q, warmup=warmup)
    if algorithm == "efficient_adam":
        return bits_efficient_adam(d, n_clients, q, bits=quant_bits)
    raise ValueError(algorithm)


def _wire_bits_one(algorithm: str, sizes, alpha, mask_scope: str,
                   exact_topk: bool, *, warmup: bool, quant_bits: int,
                   q: int) -> int:
    """Wire-exact bits for ONE client (lazy import: wire pulls in jax,
    which this accounting module otherwise never needs)."""
    from repro.core import wire
    if q != wire.VALUE_BITS:
        raise ValueError(
            f"the wire format ships f32 side streams; q={q} has no "
            f"wire-exact count (only q={wire.VALUE_BITS})")
    d = sum(int(n) for n in sizes)
    if algorithm == "fedadam" or (algorithm == "onebit_adam" and warmup):
        return wire.dense_wire_bits(sizes, 3)
    if algorithm == "fedsgd":
        return wire.dense_wire_bits(sizes, 1)
    if algorithm in ("fedadam_top", "fedadam_ssm", "ssm_m", "ssm_v",
                     "fairness_top"):
        if alpha is None:
            raise ValueError(
                f"wire-exact bits for {algorithm!r} need alpha")
        return wire.mask_wire_bits(sizes, alpha, mask_scope, exact_topk,
                                   shared=algorithm != "fedadam_top")
    if algorithm == "onebit_adam":
        return wire.sign_wire_bits(sizes)
    if algorithm == "efficient_adam":
        return wire.bbit_wire_bits(sizes, quant_bits)
    raise ValueError(algorithm)
