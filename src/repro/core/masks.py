"""Shared-sparse-mask (SSM) rules — Section V of the paper.

Given the three local update pytrees (dW, dM, dV) produce ONE boolean mask
pytree applied to all three:

* ``ssm_w``      — mask = Top_k(|dW|).  The paper's OPTIMAL rule (Eq. 28):
                   by Proposition 1, Gamma > Theta > Lambda, and empirically
                   |dW| >> |dM| >> |dV| (Fig. 1), so minimizing the dominant
                   Gamma-term of the Theorem-1 divergence bound reduces to
                   keeping the largest entries of dW.
* ``ssm_m``      — mask from |dM| (baseline FedAdam-SSM_M).
* ``ssm_v``      — mask from |dV| (baseline FedAdam-SSM_V).
* ``fairness_top`` — mask from the *union* of the three tensors
                   (Fairness-Top [40]): each tensor is magnitude-normalized
                   so all three compete fairly, then one top-k over the
                   elementwise max of the normalized scores.
* ``top``        — NOT a shared mask: three independent Top_k masks
                   (FedAdam-Top, Section IV).  Returned as a 3-tuple.

Consumed by the top-k compressors (core/compressors/topk.py,
docs/compressors.md); the rule string is a compressor-construction
parameter, never dispatched on inside the FL round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparsify as S

_F32 = jnp.float32

SHARED_RULES = ("ssm_w", "ssm_m", "ssm_v", "fairness_top")


def shared_score_tree(rule: str, dW, dM, dV):
    """Score tensors whose |.| the shared mask thresholds — the input to
    both the mask construction here and the fused kernel compress path
    (core/sparsify.tree_shared_compress_fused).  Returns ``None`` for
    ``ssm_w``: the score IS dW, and the fused kernel then derives the
    mask from the dW stream it already reads instead of streaming a
    separate score tensor."""
    if rule == "ssm_w":
        return None
    if rule == "ssm_m":
        return dM
    if rule == "ssm_v":
        return dV
    if rule == "fairness_top":
        def union(w, m, v):
            def norm(x):
                n = jnp.sqrt(jnp.sum(x.astype(_F32) ** 2)) + 1e-30
                return jnp.abs(x.astype(_F32)) / n
            return jnp.maximum(norm(w), jnp.maximum(norm(m), norm(v)))
        return jax.tree.map(union, dW, dM, dV)
    raise ValueError(f"unknown shared mask rule {rule!r}")


def shared_mask(rule: str, dW, dM, dV, alpha: float,
                scope: str = "per_tensor", exact: bool = True,
                backend=None):
    score = shared_score_tree(rule, dW, dM, dV)
    score = jax.tree.map(jnp.abs, dW if score is None else score)
    return S.tree_topk_masks(score, alpha, scope=scope, exact=exact,
                             backend=backend)


def independent_masks(dW, dM, dV, alpha: float, scope: str = "per_tensor",
                      exact: bool = True, backend=None):
    """FedAdam-Top: three separate Top_k masks."""
    mk = lambda t: S.tree_topk_masks(
        jax.tree.map(jnp.abs, t), alpha, scope=scope, exact=exact,
        backend=backend)
    return mk(dW), mk(dM), mk(dV)
