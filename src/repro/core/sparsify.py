"""Top-k sparsification primitives (Definition 1 & 2 of the paper).

Two mask constructions:

* ``topk_mask_exact`` — scatter of the exact top-k indices (|mask| == k
  always; ties broken by index order).  O(d log d) sort-based; used for
  small models, tests and anywhere exactness matters.
* ``topk_mask_threshold`` — mask = |x| >= tau with tau chosen by the
  O(d)-per-pass bisection the ``topk_mask`` Pallas kernel implements;
  |mask| may exceed k by ties.  This is the production path for d ~ 1e9+.

Masks are computed per-tensor ("per_tensor" scope, k_i = ceil(alpha * n_i))
or over the concatenated flat model ("global" scope — the paper's exact
formulation; feasible when the model fits one host).

These are the primitives under the top-k compressors in
core/compressors/topk.py (see docs/compressors.md).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

_F32 = jnp.float32


def k_for(n: int, alpha: float) -> int:
    """Number of kept elements for a tensor of n elements (>=1)."""
    return max(1, int(round(alpha * n)))


# Tensors larger than BLOCK elements use *blocked* top-k: the flat tensor is
# tiled into BLOCK-sized rows and top-(alpha*BLOCK) is taken per row.  This
# (a) keeps every index within int32 (XLA scatter/gather requirement —
# stacked MoE leaves reach 3e11 elements), (b) is embarrassingly shardable,
# and (c) is the standard practical surrogate for global top-k (same
# k-contraction factor per block).  Leaves <= BLOCK use exact top-k.
BLOCK = 1 << 20


def blocked_topk_mask(x: jax.Array, alpha: float,
                      block: int = BLOCK) -> jax.Array:
    """Exact top-k within each BLOCK-sized tile of flat x."""
    flat = x.reshape(-1)
    n = flat.size
    nb = -(-n // block)
    pad = nb * block - n
    a = jnp.abs(jnp.pad(flat, (0, pad))).reshape(nb, block)
    k = k_for(block, alpha)
    _, idx = lax.top_k(a, k)                      # (nb, k) int32 local
    mask = jnp.zeros((nb, block), bool)
    rows = jnp.broadcast_to(jnp.arange(nb)[:, None], idx.shape)
    mask = mask.at[rows, idx].set(True)
    return mask.reshape(-1)[:n].reshape(x.shape)


def topk_mask_exact(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest-|.| elements of flat/ND x."""
    flat = jnp.abs(x.reshape(-1))
    _, idx = lax.top_k(flat, k)
    mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
    return mask.reshape(x.shape)


def topk_mask_threshold(x: jax.Array, k: int, iters: int = 24) -> jax.Array:
    """Threshold-bisection mask (ties may push count above k).

    Pure-jnp reference of the Pallas ``topk_mask`` kernel: binary-search a
    threshold tau in [0, max|x|] such that count(|x| >= tau) ~ k, then mask.

    SHAPE-PRESERVING on purpose: no reshape/flatten — reductions over the
    (possibly mesh-sharded) dims lower to partial-reduce + tiny all-reduce,
    whereas a flatten of a sharded tensor forces a full all-gather.  Counts
    accumulate in f32 (exact to 2^24 per partial; bisection tolerance far
    coarser than the rounding).
    """
    a = jnp.abs(x).astype(_F32)
    hi = jnp.max(a)
    lo = jnp.zeros((), _F32)
    kf = jnp.asarray(k, _F32)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(_F32))
        # too many kept -> raise threshold (move lo up)
        lo, hi = jnp.where(cnt > kf, mid, lo), jnp.where(cnt > kf, hi, mid)
        return lo, hi

    lo, hi = lax.fori_loop(0, iters, body, (lo, hi))
    # `lo` keeps count >= k; guard the degenerate all-equal case by falling
    # back to hi when lo never moved.
    tau = jnp.where(jnp.sum((a >= lo).astype(_F32)) >= kf, lo, hi)
    return a >= tau


def sparsify(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Top_k(x) = x . mask (Definition 1)."""
    return jnp.where(mask, x, jnp.zeros((), x.dtype))


def compress_to_coo(x: jax.Array, mask_idx: jax.Array) -> jax.Array:
    """Gather the k masked values (mask_idx: (k,) int32 into flat x)."""
    return jnp.take(x.reshape(-1), mask_idx)


def mask_indices(mask: jax.Array, k: int) -> jax.Array:
    """Indices of the k True entries of mask (flat order).  Requires the
    mask to have >= k set bits (exact construction guarantees == k)."""
    score = mask.reshape(-1).astype(jnp.int8)
    _, idx = lax.top_k(score, k)
    return jnp.sort(idx)


def scatter_from_coo(values: jax.Array, idx: jax.Array, n: int,
                     dtype=None) -> jax.Array:
    out = jnp.zeros((n,), dtype or values.dtype)
    return out.at[idx].add(values)


# ---------------------------------------------------------------------------
# Pytree-level helpers
# ---------------------------------------------------------------------------


def tree_topk_masks(score_tree, alpha: float, scope: str = "per_tensor",
                    exact: bool = True):
    """Boolean mask pytree selecting ~alpha of the elements of score_tree
    by magnitude.  scope="global" ranks across the whole flattened model
    (the paper's Definition 1 applied to the full d-vector)."""
    def mk(s, k):
        if not exact:
            # production path: O(n) streaming threshold bisection — no
            # sort, O(1) temp memory (this is what the topk_mask Pallas
            # kernel implements on TPU)
            return topk_mask_threshold(s, k)
        if s.size > BLOCK:
            return blocked_topk_mask(s, alpha)
        return topk_mask_exact(s, k)

    if scope == "per_tensor":
        return jax.tree.map(lambda s: mk(s, k_for(s.size, alpha)), score_tree)
    flat, unravel = ravel_pytree(score_tree)
    mask_flat = mk(flat, k_for(flat.size, alpha))
    return unravel_bool(mask_flat, score_tree)


def unravel_bool(mask_flat, like_tree):
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    out, off = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(mask_flat[off:off + n].reshape(leaf.shape))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_sparsify(tree, masks):
    return jax.tree.map(sparsify, tree, masks)


def tree_sparsity_error(tree, masks):
    """|| (1 - mask) . x ||_2 over the whole pytree (Theorem 1 terms)."""
    sq = jax.tree.map(
        lambda x, m: jnp.sum(jnp.where(m, 0.0, x.astype(_F32)) ** 2),
        tree, masks)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def tree_norm(tree):
    sq = jax.tree.map(lambda x: jnp.sum(x.astype(_F32) ** 2), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))
