"""Top-k sparsification primitives (Definition 1 & 2 of the paper).

Two mask constructions:

* ``topk_mask_exact`` — scatter of the exact top-k indices (|mask| == k
  always; ties broken by index order).  O(d log d) sort-based; used for
  small models, tests and anywhere exactness matters.
* ``topk_mask_threshold`` — mask = |x| >= tau with tau chosen by the
  O(d)-per-pass bisection the ``topk_mask`` Pallas kernel implements;
  |mask| may exceed k by ties.  This is the production path for d ~ 1e9+.

Masks are computed per-tensor ("per_tensor" scope, k_i = ceil(alpha * n_i))
or over the concatenated flat model ("global" scope — the paper's exact
formulation; feasible when the model fits one host).

These are the primitives under the top-k compressors in
core/compressors/topk.py (see docs/compressors.md).

Backend dispatch
----------------
Threshold-mask construction and the fused shared-mask compress have two
interchangeable implementations: the streaming Pallas kernels
(kernels/topk_mask + kernels/ssm_apply + kernels/packed_topk) and the
pure-jnp references in this module.  :func:`resolve_backend` picks one —
``auto`` routes TPU to the kernels and everything else to the
references; a ``FedConfig``/compressor ``sparsify_backend`` field or the
``REPRO_SPARSIFY_BACKEND`` environment variable forces either
(``kernel`` off-TPU runs the kernels in Pallas interpret mode, which is
how CPU CI exercises them).

Packed cohort layer
-------------------
On the kernel path, :class:`PackedLayout` flattens every pytree leaf
into ONE (8, 128)-tile-aligned buffer so the whole-model compress costs
exactly TWO Pallas launches instead of 4 per leaf:
:func:`tree_shared_compress_packed` (shared mask, the default under
:func:`tree_shared_compress_fused`) and
:func:`tree_independent_compress_packed` (FedAdam-Top's three masks,
one buffer, per-stream tau segments).  Outputs are bit-identical to the
per-leaf path.  Rules, layout and launch accounting: docs/kernels.md.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.flatten_util import ravel_pytree

from repro.kernels.packed_topk.ops import (
    packed_apply_ef, packed_hist_kernel, packed_mask_apply)
from repro.kernels.packed_topk.packed_topk import (
    BLOCK_ELEMS as PACK_BLOCK_ELEMS, LANES as PACK_LANES)
from repro.kernels.packed_topk.ref import refine_taus
from repro.kernels.ssm_apply.ops import ssm_apply_ef
from repro.kernels.topk_mask.ops import select_tau_kernel, topk_mask_kernel
from repro.kernels.topk_mask.ref import log2_taus

_F32 = jnp.float32

#: Environment override for the sparsifier backend (see resolve_backend).
SPARSIFY_BACKEND_ENV = "REPRO_SPARSIFY_BACKEND"

_BACKENDS = ("auto", "kernel", "reference")


def resolve_backend(override: Optional[str] = None) -> str:
    """Resolve the sparsifier backend to ``kernel`` | ``reference``.

    Priority: explicit non-auto ``override`` (config) >
    ``REPRO_SPARSIFY_BACKEND`` (env) > auto rule (TPU -> kernel,
    CPU/GPU -> reference).  Off-TPU the kernel backend runs in Pallas
    interpret mode (kernels/*/ops.py), so forcing ``kernel`` is valid —
    and is exactly what the parity tests do."""
    choice = (override or "auto").lower()
    if choice == "auto":
        choice = os.environ.get(SPARSIFY_BACKEND_ENV, "auto").lower()
    if choice not in _BACKENDS:
        raise ValueError(
            f"sparsify backend {choice!r} not in {_BACKENDS}")
    if choice == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "reference"
    return choice


def use_kernel_path(override: Optional[str] = None) -> bool:
    return resolve_backend(override) == "kernel"


def k_for(n: int, alpha: float) -> int:
    """Number of kept elements for a tensor of n elements (>=1).

    Static by construction: every hot-path caller passes a Python shape
    int and the config alpha, so the host cast runs at trace time — this
    is the one blessed host-math site (jit-hazard treats calls to it as
    static; the definition itself carries the suppression)."""
    return max(1, int(round(alpha * n)))  # repro-lint: disable=jit-hazard


# Tensors larger than BLOCK elements use *blocked* top-k: the flat tensor is
# tiled into BLOCK-sized rows and top-(alpha*BLOCK) is taken per row.  This
# (a) keeps every index within int32 (XLA scatter/gather requirement —
# stacked MoE leaves reach 3e11 elements), (b) is embarrassingly shardable,
# and (c) is the standard practical surrogate for global top-k (same
# k-contraction factor per block).  Leaves <= BLOCK use exact top-k.
BLOCK = 1 << 20


def blocked_topk_mask(x: jax.Array, alpha: float,
                      block: int = BLOCK) -> jax.Array:
    """Exact top-k within each BLOCK-sized tile of flat x."""
    flat = x.reshape(-1)
    n = flat.size
    nb = -(-n // block)
    pad = nb * block - n
    a = jnp.abs(jnp.pad(flat, (0, pad))).reshape(nb, block)
    k = k_for(block, alpha)
    _, idx = lax.top_k(a, k)                      # (nb, k) int32 local
    mask = jnp.zeros((nb, block), bool)
    rows = jnp.broadcast_to(jnp.arange(nb)[:, None], idx.shape)
    mask = mask.at[rows, idx].set(True)
    return mask.reshape(-1)[:n].reshape(x.shape)


def topk_mask_exact(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest-|.| elements of flat/ND x."""
    flat = jnp.abs(x.reshape(-1))
    _, idx = lax.top_k(flat, k)
    mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
    return mask.reshape(x.shape)


def topk_mask_threshold(x: jax.Array, k: int, iters: int = 24) -> jax.Array:
    """Threshold-bisection mask (ties may push count above k).

    Pure-jnp reference of the Pallas ``topk_mask`` kernel: binary-search a
    threshold tau in [0, max|x|] such that count(|x| >= tau) ~ k, then mask.

    SHAPE-PRESERVING on purpose: no reshape/flatten — reductions over the
    (possibly mesh-sharded) dims lower to partial-reduce + tiny all-reduce,
    whereas a flatten of a sharded tensor forces a full all-gather.  Counts
    accumulate in f32 (exact to 2^24 per partial; bisection tolerance far
    coarser than the rounding).
    """
    a = jnp.abs(x).astype(_F32)
    hi = jnp.max(a)
    lo = jnp.zeros((), _F32)
    kf = jnp.asarray(k, _F32)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(_F32))
        # too many kept -> raise threshold (move lo up)
        lo, hi = jnp.where(cnt > kf, mid, lo), jnp.where(cnt > kf, hi, mid)
        return lo, hi

    lo, hi = lax.fori_loop(0, iters, body, (lo, hi))
    # `lo` keeps count >= k; guard the degenerate all-equal case by falling
    # back to hi when lo never moved.
    tau = jnp.where(jnp.sum((a >= lo).astype(_F32)) >= kf, lo, hi)
    return a >= tau


def sparsify(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Top_k(x) = x . mask (Definition 1)."""
    return jnp.where(mask, x, jnp.zeros((), x.dtype))


def compress_to_coo(x: jax.Array, mask_idx: jax.Array) -> jax.Array:
    """Gather the k masked values (mask_idx: (k,) int32 into flat x)."""
    return jnp.take(x.reshape(-1), mask_idx)


def mask_indices(mask: jax.Array, k: int) -> jax.Array:
    """Indices of the k True entries of mask (flat order).  Requires the
    mask to have >= k set bits (exact construction guarantees == k)."""
    score = mask.reshape(-1).astype(jnp.int8)
    _, idx = lax.top_k(score, k)
    return jnp.sort(idx)


def scatter_from_coo(values: jax.Array, idx: jax.Array, n: int,
                     dtype=None) -> jax.Array:
    out = jnp.zeros((n,), dtype or values.dtype)
    return out.at[idx].add(values)


# ---------------------------------------------------------------------------
# Pytree-level helpers
# ---------------------------------------------------------------------------


def tree_topk_masks(score_tree, alpha: float, scope: str = "per_tensor",
                    exact: bool = True, backend: Optional[str] = None):
    """Boolean mask pytree selecting ~alpha of the elements of score_tree
    by magnitude.  scope="global" ranks across the whole flattened model
    (the paper's Definition 1 applied to the full d-vector).  The
    threshold (``exact=False``) production path dispatches per
    :func:`resolve_backend`: the streaming 3-pass Pallas kernel, or the
    jnp bisection reference."""
    def mk(s, k):
        if not exact:
            # production path: O(n) streaming threshold selection — no
            # sort, O(1) temp memory
            if use_kernel_path(backend):
                return topk_mask_kernel(s, k)[0]
            return topk_mask_threshold(s, k)
        if s.size > BLOCK:
            return blocked_topk_mask(s, alpha)
        return topk_mask_exact(s, k)

    if scope == "per_tensor":
        return jax.tree.map(lambda s: mk(s, k_for(s.size, alpha)), score_tree)
    flat, unravel = ravel_pytree(score_tree)
    mask_flat = mk(flat, k_for(flat.size, alpha))
    return unravel_bool(mask_flat, score_tree)


def unravel_bool(mask_flat, like_tree):
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    out, off = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(mask_flat[off:off + n].reshape(leaf.shape))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_sparsify(tree, masks):
    return jax.tree.map(sparsify, tree, masks)


def tree_sparsity_error(tree, masks):
    """|| (1 - mask) . x ||_2 over the whole pytree (Theorem 1 terms)."""
    sq = jax.tree.map(
        lambda x, m: jnp.sum(jnp.where(m, 0.0, x.astype(_F32)) ** 2),
        tree, masks)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def tree_norm(tree):
    sq = jax.tree.map(lambda x: jnp.sum(x.astype(_F32) ** 2), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


# ---------------------------------------------------------------------------
# Packed cohort layout — every leaf through ONE buffer, 2 launches total
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static descriptor of a multi-leaf packed buffer.

    Every leaf is flattened and zero-padded to a multiple of the
    (8, 128) f32 min tile (``PACK_BLOCK_ELEMS`` = 1024 elements), then
    the leaves are concatenated into one (R, 128) buffer.  All fields
    are Python/static, so :meth:`unpack` is shape-only slicing (no
    data-dependent work) and the layout never forces a host sync.

    ``seg_of_leaf`` maps each leaf to its tau *segment*: identity for
    scope="per_tensor", all-zeros for scope="global", and stream ids
    for the independent compressor's 3-stream packing — the kernels
    only ever see block->segment ids, so every scope is the same two
    launches.  ``seg_ids`` (block->segment, one entry per (8, 128)
    block) is the scalar-prefetch operand of both packed kernels.
    """

    shapes: tuple
    sizes: tuple
    padded: tuple
    offsets: tuple
    seg_of_leaf: tuple
    num_segments: int
    seg_sizes: tuple
    seg_ids: jax.Array = dataclasses.field(compare=False, repr=False)

    @property
    def num_leaves(self) -> int:
        return len(self.sizes)

    @property
    def total(self) -> int:
        return sum(self.padded)

    @property
    def num_blocks(self) -> int:
        return self.total // PACK_BLOCK_ELEMS

    def pack(self, leaves: Sequence[jax.Array]) -> jax.Array:
        """Flatten + pad + concatenate into the (R, 128) buffer.  All
        offsets are static, so this lowers to dynamic_update_slices a
        compiler can turn into plain copies."""
        dtype = leaves[0].dtype
        buf = jnp.zeros((self.total,), dtype)
        for leaf, off in zip(leaves, self.offsets):
            buf = lax.dynamic_update_slice(
                buf, leaf.reshape(-1).astype(dtype), (off,))
        return buf.reshape(-1, PACK_LANES)

    def unpack(self, buf: jax.Array) -> list:
        """Shape-only inverse of :meth:`pack` (padding discarded)."""
        flat = buf.reshape(-1)
        return [flat[off:off + n].reshape(shape) for off, n, shape
                in zip(self.offsets, self.sizes, self.shapes)]


def plan_packed_layout(leaves, groups: Optional[Sequence[int]] = None
                       ) -> PackedLayout:
    """Build the static :class:`PackedLayout` for a list of leaves.

    ``groups`` assigns each leaf to a tau segment (default: one segment
    per leaf, i.e. scope="per_tensor").  Segment ids must be dense in
    ``range(max+1)``; a segment's leaves need not be contiguous in the
    buffer — the kernels accumulate by block segment id."""
    shapes = tuple(tuple(leaf.shape) for leaf in leaves)
    sizes = tuple(int(leaf.size) for leaf in leaves)
    padded = tuple(-(-n // PACK_BLOCK_ELEMS) * PACK_BLOCK_ELEMS
                   for n in sizes)
    offsets, off = [], 0
    for p in padded:
        offsets.append(off)
        off += p
    if groups is None:
        groups = range(len(sizes))
    # groups is always a host-side list of Python ints — the layout is
    # static by construction, never built from traced values
    seg_of_leaf = tuple(int(g) for g in groups)  # repro-lint: disable=jit-hazard
    num_segments = max(seg_of_leaf) + 1
    seg_sizes = [0] * num_segments
    for n, g in zip(sizes, seg_of_leaf):
        seg_sizes[g] += n
    seg_ids = jnp.asarray(np.concatenate(
        [np.full(p // PACK_BLOCK_ELEMS, g, np.int32)
         for p, g in zip(padded, seg_of_leaf)]))
    return PackedLayout(shapes=shapes, sizes=sizes, padded=padded,
                        offsets=tuple(offsets), seg_of_leaf=seg_of_leaf,
                        num_segments=num_segments,
                        seg_sizes=tuple(seg_sizes), seg_ids=seg_ids)


def _segment_absmax(layout: PackedLayout, score_leaves):
    """Per-segment max|x| as a list of f32 scalars.  max is exact, so
    the reduce over a segment's leaves is bitwise the raveled max the
    per-leaf global path computes."""
    per_leaf = [jnp.max(jnp.abs(leaf.astype(_F32)))
                for leaf in score_leaves]
    out = [None] * layout.num_segments
    for am, g in zip(per_leaf, layout.seg_of_leaf):
        out[g] = am if out[g] is None else jnp.maximum(out[g], am)
    return out


def _packed_select_inputs(layout: PackedLayout, score_leaves, score_p,
                          alpha: float):
    """Launch 1 (histogram) + the host-side CDF refine.  Returns the
    prefetch operands of the apply launch: (taus2, ks, ns)."""
    ks = jnp.asarray([k_for(n, alpha) for n in layout.seg_sizes], _F32)
    ns = jnp.asarray(layout.seg_sizes, _F32)
    absmax = _segment_absmax(layout, score_leaves)
    edges = jnp.stack([log2_taus(a) for a in absmax])
    c1 = packed_hist_kernel(score_p, layout.seg_ids, edges)
    taus2 = refine_taus(c1, edges, absmax, ks)
    return taus2, ks, ns


def _leaf_masks(layout: PackedLayout, score_leaves, taus):
    """Diagnostic boolean masks, recomputed per leaf from tau (same
    compare the kernels use; XLA fuses it into consuming reductions)."""
    return [jnp.abs(leaf.astype(_F32)) >= taus[g]
            for leaf, g in zip(score_leaves, layout.seg_of_leaf)]


def _uniform_dtype(*trees) -> bool:
    dts = {leaf.dtype for t in trees if t is not None
           for leaf in jax.tree_util.tree_leaves(t)}
    return len(dts) == 1


def tree_shared_compress_packed(score_tree, dW, dM, dV, alpha: float,
                                scope: str = "per_tensor", *,
                                value_dtype=None,
                                with_residual: bool = False):
    """Packed realization of the shared-mask compress: every leaf of
    (score, dW, dM, dV) rides ONE tile-aligned buffer, and the whole
    cohort costs exactly TWO Pallas launches — the segmented histogram
    and the fused refine-count/tau-pick/apply pass — plus the jnp
    absmax reduction and the O(L * N_BINS) host refine.

    tau per segment is bitwise equal to the per-leaf
    ``select_tau_kernel`` tau (same candidates, same pick), so outputs
    — masks, wire-cast values, the EF residual — are bit-identical to
    :func:`tree_shared_compress_fused`'s per-leaf path.  Same return
    shape: ``(sW, sM, sV, err_tree | None, mask_tree)``."""
    w_leaves, treedef = jax.tree_util.tree_flatten(dW)
    m_leaves = treedef.flatten_up_to(dM)
    v_leaves = treedef.flatten_up_to(dV)
    s_leaves = (None if score_tree is None
                else treedef.flatten_up_to(score_tree))
    groups = None if scope == "per_tensor" else [0] * len(w_leaves)
    layout = plan_packed_layout(w_leaves, groups)

    wp = layout.pack(w_leaves)
    mp = layout.pack(m_leaves)
    vp = layout.pack(v_leaves)
    sp = None if s_leaves is None else layout.pack(s_leaves)
    score_leaves = w_leaves if s_leaves is None else s_leaves

    taus2, ks, ns = _packed_select_inputs(
        layout, score_leaves, wp if sp is None else sp, alpha)
    outs = packed_apply_ef(taus2, layout.seg_ids, ks, ns, wp, mp, vp, sp,
                           with_residual=with_residual,
                           value_dtype=value_dtype)
    taus = outs[-2][:, 0]
    unflat = lambda buf: jax.tree_util.tree_unflatten(
        treedef, layout.unpack(buf))
    err_tree = unflat(outs[3]) if with_residual else None
    mask_tree = jax.tree_util.tree_unflatten(
        treedef, _leaf_masks(layout, score_leaves, taus))
    return unflat(outs[0]), unflat(outs[1]), unflat(outs[2]), err_tree, \
        mask_tree


def tree_independent_compress_packed(dW, dM, dV, alpha: float,
                                     scope: str = "per_tensor", *,
                                     value_dtype=None,
                                     with_residual: bool = False):
    """Packed compress for the THREE-mask (FedAdam-Top) scheme: all
    leaves of dW ++ dM ++ dV share one packed buffer, each stream's
    leaves in their own tau segments (3L segments for "per_tensor",
    3 for "global") — so three independent top-k selections still cost
    the same TWO launches.  Each segment's score is the stream itself.

    Returns ``(sW, sM, sV, err_tree | None, (mW, mM, mV))``; the
    residual is dW's (the M/V rows of the kernel's residual output are
    discarded, matching the composed path's EF contract)."""
    w_leaves, treedef = jax.tree_util.tree_flatten(dW)
    m_leaves = treedef.flatten_up_to(dM)
    v_leaves = treedef.flatten_up_to(dV)
    leaves = w_leaves + m_leaves + v_leaves
    L = len(w_leaves)
    if scope == "per_tensor":
        groups = list(range(3 * L))
    else:
        groups = [0] * L + [1] * L + [2] * L
    layout = plan_packed_layout(leaves, groups)

    xp = layout.pack(leaves)
    taus2, ks, ns = _packed_select_inputs(layout, leaves, xp, alpha)
    outs = packed_mask_apply(taus2, layout.seg_ids, ks, ns, xp,
                             with_residual=with_residual,
                             value_dtype=value_dtype)
    taus = outs[-2][:, 0]
    sx = layout.unpack(outs[0])
    unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    err_tree = (unflat(layout.unpack(outs[1])[:L])
                if with_residual else None)
    masks = _leaf_masks(layout, leaves, taus)
    return (unflat(sx[:L]), unflat(sx[L:2 * L]), unflat(sx[2 * L:]),
            err_tree,
            (unflat(masks[:L]), unflat(masks[L:2 * L]),
             unflat(masks[2 * L:])))


# ---------------------------------------------------------------------------
# Kernel-path fused shared-mask compress
# ---------------------------------------------------------------------------


def _fused_leaf(score, w, m, v, k: int, value_dtype, with_residual: bool):
    """One leaf of the fused compress: 3-pass tau selection on the score
    (== w when score is None), then ONE fused apply/cast/residual pass.
    Returns (sw, sm, sv, err|None, mask)."""
    tau, _ = select_tau_kernel(w if score is None else score, k)
    outs = ssm_apply_ef(tau, w, m, v, score,
                        with_residual=with_residual,
                        value_dtype=value_dtype)
    err = outs[3] if with_residual else None
    # mask reconstructed for diagnostics only (never re-materialized by
    # the kernel); XLA fuses this compare into the consuming reductions.
    s = w if score is None else score
    mask = jnp.abs(s.astype(_F32)) >= tau
    return outs[0], outs[1], outs[2], err, mask


def tree_shared_compress_fused(score_tree, dW, dM, dV, alpha: float,
                               scope: str = "per_tensor", *,
                               value_dtype=None,
                               with_residual: bool = False,
                               packed: bool = True):
    """Fused kernel-path realization of the shared-mask compress: for
    each leaf (or the raveled model when ``scope == "global"``), select
    tau with the streaming topk_mask kernel and apply mask + optional
    ``value_dtype`` wire cast + optional error-feedback residual in a
    single ``ssm_apply_ef`` pass.

    ``score_tree=None`` means the mask scores ARE ``|dW|`` (the paper's
    optimal ssm_w rule) — the kernel then derives the mask from the dW
    stream it is already reading instead of streaming a score tensor.

    ``packed=True`` (the default) routes uniform-dtype cohorts through
    :func:`tree_shared_compress_packed` — bit-identical outputs in TWO
    Pallas launches total instead of 4 per leaf.  Mixed-dtype trees (no
    single packed buffer dtype) and ``packed=False`` take the per-leaf
    loop below.

    Returns ``(sW, sM, sV, err_tree | None, mask_tree)``; arithmetic is
    bit-identical to the composed reference ops given the same tau
    (asserted by tests/test_sparsify_dispatch.py)."""
    if packed and _uniform_dtype(score_tree, dW, dM, dV):
        return tree_shared_compress_packed(
            score_tree, dW, dM, dV, alpha, scope,
            value_dtype=value_dtype, with_residual=with_residual)
    if scope == "global":
        flat_w, unravel = ravel_pytree(dW)
        flat_m, _ = ravel_pytree(dM)
        flat_v, _ = ravel_pytree(dV)
        flat_s = None if score_tree is None else ravel_pytree(score_tree)[0]
        sw, sm, sv, err, mask = _fused_leaf(
            flat_s, flat_w, flat_m, flat_v, k_for(flat_w.size, alpha),
            value_dtype, with_residual)
        return (unravel(sw), unravel(sm), unravel(sv),
                unravel(err) if err is not None else None,
                unravel_bool(mask, dW))

    w_leaves, treedef = jax.tree_util.tree_flatten(dW)
    m_leaves = treedef.flatten_up_to(dM)
    v_leaves = treedef.flatten_up_to(dV)
    s_leaves = ([None] * len(w_leaves) if score_tree is None
                else treedef.flatten_up_to(score_tree))
    outs = [_fused_leaf(s, w, m, v, k_for(w.size, alpha), value_dtype,
                        with_residual)
            for s, w, m, v in zip(s_leaves, w_leaves, m_leaves, v_leaves)]
    unflat = lambda i: jax.tree_util.tree_unflatten(
        treedef, [o[i] for o in outs])
    err_tree = unflat(3) if with_residual else None
    return unflat(0), unflat(1), unflat(2), err_tree, unflat(4)
