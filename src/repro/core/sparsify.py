"""Top-k sparsification primitives (Definition 1 & 2 of the paper).

Two mask constructions:

* ``topk_mask_exact`` — scatter of the exact top-k indices (|mask| == k
  always; ties broken by index order).  O(d log d) sort-based; used for
  small models, tests and anywhere exactness matters.
* ``topk_mask_threshold`` — mask = |x| >= tau with tau chosen by the
  O(d)-per-pass bisection the ``topk_mask`` Pallas kernel implements;
  |mask| may exceed k by ties.  This is the production path for d ~ 1e9+.

Masks are computed per-tensor ("per_tensor" scope, k_i = ceil(alpha * n_i))
or over the concatenated flat model ("global" scope — the paper's exact
formulation; feasible when the model fits one host).

These are the primitives under the top-k compressors in
core/compressors/topk.py (see docs/compressors.md).

Backend dispatch
----------------
Threshold-mask construction and the fused shared-mask compress have two
interchangeable implementations: the streaming Pallas kernels
(kernels/topk_mask + kernels/ssm_apply) and the pure-jnp references in
this module.  :func:`resolve_backend` picks one — ``auto`` routes TPU to
the kernels and everything else to the references; a ``FedConfig``/
compressor ``sparsify_backend`` field or the ``REPRO_SPARSIFY_BACKEND``
environment variable forces either (``kernel`` off-TPU runs the kernels
in Pallas interpret mode, which is how CPU CI exercises them).  Rules
and the fused-pass contract: docs/kernels.md.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from repro.kernels.ssm_apply.ops import ssm_apply_ef
from repro.kernels.topk_mask.ops import select_tau_kernel, topk_mask_kernel

_F32 = jnp.float32

#: Environment override for the sparsifier backend (see resolve_backend).
SPARSIFY_BACKEND_ENV = "REPRO_SPARSIFY_BACKEND"

_BACKENDS = ("auto", "kernel", "reference")


def resolve_backend(override: Optional[str] = None) -> str:
    """Resolve the sparsifier backend to ``kernel`` | ``reference``.

    Priority: explicit non-auto ``override`` (config) >
    ``REPRO_SPARSIFY_BACKEND`` (env) > auto rule (TPU -> kernel,
    CPU/GPU -> reference).  Off-TPU the kernel backend runs in Pallas
    interpret mode (kernels/*/ops.py), so forcing ``kernel`` is valid —
    and is exactly what the parity tests do."""
    choice = (override or "auto").lower()
    if choice == "auto":
        choice = os.environ.get(SPARSIFY_BACKEND_ENV, "auto").lower()
    if choice not in _BACKENDS:
        raise ValueError(
            f"sparsify backend {choice!r} not in {_BACKENDS}")
    if choice == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "reference"
    return choice


def use_kernel_path(override: Optional[str] = None) -> bool:
    return resolve_backend(override) == "kernel"


def k_for(n: int, alpha: float) -> int:
    """Number of kept elements for a tensor of n elements (>=1).

    Static by construction: every hot-path caller passes a Python shape
    int and the config alpha, so the host cast runs at trace time — this
    is the one blessed host-math site (jit-hazard treats calls to it as
    static; the definition itself carries the suppression)."""
    return max(1, int(round(alpha * n)))  # repro-lint: disable=jit-hazard


# Tensors larger than BLOCK elements use *blocked* top-k: the flat tensor is
# tiled into BLOCK-sized rows and top-(alpha*BLOCK) is taken per row.  This
# (a) keeps every index within int32 (XLA scatter/gather requirement —
# stacked MoE leaves reach 3e11 elements), (b) is embarrassingly shardable,
# and (c) is the standard practical surrogate for global top-k (same
# k-contraction factor per block).  Leaves <= BLOCK use exact top-k.
BLOCK = 1 << 20


def blocked_topk_mask(x: jax.Array, alpha: float,
                      block: int = BLOCK) -> jax.Array:
    """Exact top-k within each BLOCK-sized tile of flat x."""
    flat = x.reshape(-1)
    n = flat.size
    nb = -(-n // block)
    pad = nb * block - n
    a = jnp.abs(jnp.pad(flat, (0, pad))).reshape(nb, block)
    k = k_for(block, alpha)
    _, idx = lax.top_k(a, k)                      # (nb, k) int32 local
    mask = jnp.zeros((nb, block), bool)
    rows = jnp.broadcast_to(jnp.arange(nb)[:, None], idx.shape)
    mask = mask.at[rows, idx].set(True)
    return mask.reshape(-1)[:n].reshape(x.shape)


def topk_mask_exact(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest-|.| elements of flat/ND x."""
    flat = jnp.abs(x.reshape(-1))
    _, idx = lax.top_k(flat, k)
    mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
    return mask.reshape(x.shape)


def topk_mask_threshold(x: jax.Array, k: int, iters: int = 24) -> jax.Array:
    """Threshold-bisection mask (ties may push count above k).

    Pure-jnp reference of the Pallas ``topk_mask`` kernel: binary-search a
    threshold tau in [0, max|x|] such that count(|x| >= tau) ~ k, then mask.

    SHAPE-PRESERVING on purpose: no reshape/flatten — reductions over the
    (possibly mesh-sharded) dims lower to partial-reduce + tiny all-reduce,
    whereas a flatten of a sharded tensor forces a full all-gather.  Counts
    accumulate in f32 (exact to 2^24 per partial; bisection tolerance far
    coarser than the rounding).
    """
    a = jnp.abs(x).astype(_F32)
    hi = jnp.max(a)
    lo = jnp.zeros((), _F32)
    kf = jnp.asarray(k, _F32)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(_F32))
        # too many kept -> raise threshold (move lo up)
        lo, hi = jnp.where(cnt > kf, mid, lo), jnp.where(cnt > kf, hi, mid)
        return lo, hi

    lo, hi = lax.fori_loop(0, iters, body, (lo, hi))
    # `lo` keeps count >= k; guard the degenerate all-equal case by falling
    # back to hi when lo never moved.
    tau = jnp.where(jnp.sum((a >= lo).astype(_F32)) >= kf, lo, hi)
    return a >= tau


def sparsify(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Top_k(x) = x . mask (Definition 1)."""
    return jnp.where(mask, x, jnp.zeros((), x.dtype))


def compress_to_coo(x: jax.Array, mask_idx: jax.Array) -> jax.Array:
    """Gather the k masked values (mask_idx: (k,) int32 into flat x)."""
    return jnp.take(x.reshape(-1), mask_idx)


def mask_indices(mask: jax.Array, k: int) -> jax.Array:
    """Indices of the k True entries of mask (flat order).  Requires the
    mask to have >= k set bits (exact construction guarantees == k)."""
    score = mask.reshape(-1).astype(jnp.int8)
    _, idx = lax.top_k(score, k)
    return jnp.sort(idx)


def scatter_from_coo(values: jax.Array, idx: jax.Array, n: int,
                     dtype=None) -> jax.Array:
    out = jnp.zeros((n,), dtype or values.dtype)
    return out.at[idx].add(values)


# ---------------------------------------------------------------------------
# Pytree-level helpers
# ---------------------------------------------------------------------------


def tree_topk_masks(score_tree, alpha: float, scope: str = "per_tensor",
                    exact: bool = True, backend: Optional[str] = None):
    """Boolean mask pytree selecting ~alpha of the elements of score_tree
    by magnitude.  scope="global" ranks across the whole flattened model
    (the paper's Definition 1 applied to the full d-vector).  The
    threshold (``exact=False``) production path dispatches per
    :func:`resolve_backend`: the streaming 3-pass Pallas kernel, or the
    jnp bisection reference."""
    def mk(s, k):
        if not exact:
            # production path: O(n) streaming threshold selection — no
            # sort, O(1) temp memory
            if use_kernel_path(backend):
                return topk_mask_kernel(s, k)[0]
            return topk_mask_threshold(s, k)
        if s.size > BLOCK:
            return blocked_topk_mask(s, alpha)
        return topk_mask_exact(s, k)

    if scope == "per_tensor":
        return jax.tree.map(lambda s: mk(s, k_for(s.size, alpha)), score_tree)
    flat, unravel = ravel_pytree(score_tree)
    mask_flat = mk(flat, k_for(flat.size, alpha))
    return unravel_bool(mask_flat, score_tree)


def unravel_bool(mask_flat, like_tree):
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    out, off = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(mask_flat[off:off + n].reshape(leaf.shape))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_sparsify(tree, masks):
    return jax.tree.map(sparsify, tree, masks)


def tree_sparsity_error(tree, masks):
    """|| (1 - mask) . x ||_2 over the whole pytree (Theorem 1 terms)."""
    sq = jax.tree.map(
        lambda x, m: jnp.sum(jnp.where(m, 0.0, x.astype(_F32)) ** 2),
        tree, masks)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def tree_norm(tree):
    sq = jax.tree.map(lambda x: jnp.sum(x.astype(_F32) ** 2), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


# ---------------------------------------------------------------------------
# Kernel-path fused shared-mask compress
# ---------------------------------------------------------------------------


def _fused_leaf(score, w, m, v, k: int, value_dtype, with_residual: bool):
    """One leaf of the fused compress: 3-pass tau selection on the score
    (== w when score is None), then ONE fused apply/cast/residual pass.
    Returns (sw, sm, sv, err|None, mask)."""
    tau, _ = select_tau_kernel(w if score is None else score, k)
    outs = ssm_apply_ef(tau, w, m, v, score,
                        with_residual=with_residual,
                        value_dtype=value_dtype)
    err = outs[3] if with_residual else None
    # mask reconstructed for diagnostics only (never re-materialized by
    # the kernel); XLA fuses this compare into the consuming reductions.
    s = w if score is None else score
    mask = jnp.abs(s.astype(_F32)) >= tau
    return outs[0], outs[1], outs[2], err, mask


def tree_shared_compress_fused(score_tree, dW, dM, dV, alpha: float,
                               scope: str = "per_tensor", *,
                               value_dtype=None,
                               with_residual: bool = False):
    """Fused kernel-path realization of the shared-mask compress: for
    each leaf (or the raveled model when ``scope == "global"``), select
    tau with the streaming topk_mask kernel and apply mask + optional
    ``value_dtype`` wire cast + optional error-feedback residual in a
    single ``ssm_apply_ef`` pass.

    ``score_tree=None`` means the mask scores ARE ``|dW|`` (the paper's
    optimal ssm_w rule) — the kernel then derives the mask from the dW
    stream it is already reading instead of streaming a score tensor.

    Returns ``(sW, sM, sV, err_tree | None, mask_tree)``; arithmetic is
    bit-identical to the composed reference ops given the same tau
    (asserted by tests/test_sparsify_dispatch.py)."""
    if scope == "global":
        flat_w, unravel = ravel_pytree(dW)
        flat_m, _ = ravel_pytree(dM)
        flat_v, _ = ravel_pytree(dV)
        flat_s = None if score_tree is None else ravel_pytree(score_tree)[0]
        sw, sm, sv, err, mask = _fused_leaf(
            flat_s, flat_w, flat_m, flat_v, k_for(flat_w.size, alpha),
            value_dtype, with_residual)
        return (unravel(sw), unravel(sm), unravel(sv),
                unravel(err) if err is not None else None,
                unravel_bool(mask, dW))

    w_leaves, treedef = jax.tree_util.tree_flatten(dW)
    m_leaves = treedef.flatten_up_to(dM)
    v_leaves = treedef.flatten_up_to(dV)
    s_leaves = ([None] * len(w_leaves) if score_tree is None
                else treedef.flatten_up_to(score_tree))
    outs = [_fused_leaf(s, w, m, v, k_for(w.size, alpha), value_dtype,
                        with_residual)
            for s, w, m, v in zip(s_leaves, w_leaves, m_leaves, v_leaves)]
    unflat = lambda i: jax.tree_util.tree_unflatten(
        treedef, [o[i] for o in outs])
    err_tree = unflat(3) if with_residual else None
    return unflat(0), unflat(1), unflat(2), err_tree, unflat(4)
