"""Theorem 1 / Proposition 1 / Theorem 2 / Theorem 3 calculators.

These implement the paper's bound *formulas* so experiments can (a) verify
Proposition 1's ordering Gamma > Theta > Lambda under condition (26),
(b) evaluate the Theorem-1 divergence bound on measured deltas, and
(c) plot the convergence-rate terms of Theorems 2/3 against the sweeps in
Figs. 3-5.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class BoundParams:
    """Problem constants of Assumptions 1-3 + Adam hyperparameters."""
    d: int                    # model dimension
    G: float                  # gradient bound (Assumption 2)
    rho: float                # Lipschitz constant (Assumption 1)
    sigma_l: float            # local variance (Assumption 3)
    sigma_g: float            # global variance (Assumption 3)
    eta: float                # learning rate
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-6
    D_n: float = 1.0          # local batch size |D~_n|


def phi(p: BoundParams) -> float:
    """Eq. (21)."""
    return p.beta1 / math.sqrt(p.beta2)


def psi(p: BoundParams) -> float:
    """Eq. (22)."""
    return 1.0 + p.beta1 / math.sqrt(p.beta2) + \
        (p.eta * p.rho * (1 - p.beta1) / math.sqrt(p.eps)) * \
        (1 + (1 - p.beta2) * p.d * p.G ** 2 / p.eps)


def chi(p: BoundParams) -> float:
    """Eq. (23)."""
    t1 = p.d * p.G * p.eta * (
        (2 * p.beta1 * (1 - math.sqrt(p.beta2))
         / (p.eps * math.sqrt(p.eps * p.beta2))) * (p.G ** 2 + p.eps)
        + ((1 - p.beta1) * p.beta2 / (p.eps * math.sqrt(p.eps))) * p.G ** 2)
    t2 = ((1 - p.beta1) * p.eta *
          (p.sigma_l / math.sqrt(p.D_n) + p.sigma_g) / math.sqrt(p.eps)) * \
        (1 + (1 - p.beta2) * p.d * p.G ** 2 / p.eps)
    return t1 + t2


def _roots(p: BoundParams):
    """The two roots (psi +- sqrt(psi^2 + 4 phi)) / 2 of the recurrence."""
    ps, ph = psi(p), phi(p)
    disc = math.sqrt(ps ** 2 + 4 * ph)
    return (ps - disc) / 2.0, (ps + disc) / 2.0, disc


def gamma(p: BoundParams, l: int) -> float:
    """Eq. (17) — weight of ||dW|| in the divergence bound."""
    r_minus, r_plus, disc = _roots(p)
    ph = phi(p)
    c = p.d * p.G ** 2 * p.eta * p.rho / (p.eps * math.sqrt(p.eps)) \
        * p.beta1 * (1 - p.beta2)
    term1 = (r_minus ** l) * (ph + (disc - psi(p)) / 2.0 - c)
    term2 = ((disc + psi(p)) / 2.0 - ph + c) * (r_plus ** l)
    return (term1 + term2) / disc


def lam(p: BoundParams, l: int) -> float:
    """Eq. (18) — weight of ||dM||."""
    r_minus, r_plus, disc = _roots(p)
    return (p.eta * p.beta1 / (math.sqrt(p.eps) * disc)) * \
        (r_plus ** l - r_minus ** l)


def theta(p: BoundParams, l: int) -> float:
    """Eq. (19) — weight of ||dV||."""
    r_minus, r_plus, disc = _roots(p)
    return (math.sqrt(p.d) * p.G * p.eta * p.beta2
            / (2 * p.eps * math.sqrt(p.eps) * disc)) * \
        (r_plus ** l - r_minus ** l)


def phi_const(p: BoundParams, l: int) -> float:
    """Eq. (20) — data-heterogeneity floor of the divergence bound."""
    r_minus, r_plus, disc = _roots(p)
    ps, ph = psi(p), phi(p)
    sig = p.sigma_l / math.sqrt(p.D_n) + p.sigma_g
    head = (sig / disc) * (
        (p.eta / math.sqrt(p.eps)) * (1 - p.beta1)
        + (p.d * p.G ** 2 * p.eta / (p.eps * math.sqrt(p.eps))) * (1 - p.beta2)
    ) * (r_plus ** l - r_minus ** l)
    tail = (chi(p) / (1 - ps - ph)) * (
        (1.0 / disc) * ((1 - r_plus) * (r_minus ** l)
                        - (1 - r_minus) * (r_plus ** l)) + 1.0)
    return head + tail


def proposition1_condition(p: BoundParams) -> bool:
    """Eq. (26): beta2 < 1 - 1/(1 + 2 G rho sqrt(d))."""
    return p.beta2 < 1.0 - 1.0 / (1.0 + 2 * p.G * p.rho * math.sqrt(p.d))


def proposition1_holds(p: BoundParams, l: int) -> bool:
    """Gamma > Theta > Lambda (Eq. 27)."""
    return gamma(p, l) > theta(p, l) > lam(p, l)


def divergence_bound(p: BoundParams, l: int, err_w: float, err_m: float,
                     err_v: float) -> float:
    """Theorem 1 (Eq. 16): Gamma*err_w + Lambda*err_m + Theta*err_v + Phi,
    with err_* = FedAvg-weighted sparsification error norms
    ||(1 - mask) . delta||."""
    return gamma(p, l) * err_w + lam(p, l) * err_m + \
        theta(p, l) * err_v + phi_const(p, l)


# ---------------------------------------------------------------------------
# Convergence-rate bounds
# ---------------------------------------------------------------------------


def theorem2_bound(p: BoundParams, alpha: float, L: int, T: int,
                   f0_minus_fT: float) -> float:
    """Non-convex rate bound (Eq. 29), as a function of the sparsification
    ratio alpha, local epochs L and rounds T."""
    e = p.eps
    t1 = 2.0 / (p.eta * T) * f0_minus_fT
    t2 = 2.0 * ((p.eta * p.rho + 2) * (1 - alpha) + p.eta * p.rho - 1) * \
        (p.eta * p.G ** 2 * p.d * L ** 2 / e)
    geom2 = p.beta2 * (1 - p.beta2 ** L) / (1 - p.beta2)
    geom1 = 4 * p.beta1 * (1 - p.beta1 ** L) / (e * (1 - p.beta1) ** 2)
    t3 = 6 * p.G ** 2 * p.d * (
        (L - geom2) * (p.G ** 4 * p.d * L / (4 * e ** 3))
        + L ** 2 / e + geom1 + 1 + p.rho ** 2 * L ** 2 / (3 * e))
    sig = (p.sigma_l / math.sqrt(p.D_n) + p.sigma_g) ** 2
    t4 = 6 * sig
    return t1 + t2 + t3 + t4


def theorem3_bound(p: BoundParams, alpha: float, L: int, T: int,
                   mu: float, f0_minus_fstar: float) -> float:
    """PL-condition rate bound (Eq. 31)."""
    e = p.eps
    t1 = (1 - p.eta * mu) ** T * f0_minus_fstar
    t2 = (p.eta * p.G ** 2 * p.d * L ** 2 / (mu * e)) * \
        ((p.eta * p.rho + 2) * (1 - alpha) + p.eta * p.rho - 1)
    geom1 = 4 * p.beta1 * (1 - p.beta1 ** L) / (e * (1 - p.beta1) ** 2)
    geom2 = p.beta2 * (1 - p.beta2 ** L) / (1 - p.beta2)
    t3 = (3 * p.G ** 2 * p.d / mu) * (
        geom1 + L ** 2 / e + p.rho ** 2 * L ** 2 / (3 * e) + 1
        + (p.G ** 4 * p.d * L / (4 * e ** 3)) * (L - geom2))
    sig = (p.sigma_l / math.sqrt(p.D_n) + p.sigma_g) ** 2
    t4 = 3 * sig / mu
    return t1 + t2 + t3 + t4


def optimal_local_epochs(p: BoundParams, alpha: float, T: int,
                         f0_minus_fT: float) -> float:
    """Remark 6 crossover: L* = ((1-alpha) rho G^2 d /
    (eps (F0-FT) sqrt(T)))^(1/4)."""
    return ((1 - alpha) * p.rho * p.G ** 2 * p.d /
            (p.eps * max(1e-12, f0_minus_fT) * math.sqrt(T))) ** 0.25
