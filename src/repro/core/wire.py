"""The uplink wire format: what a client's payload ACTUALLY ships.

``comm.py`` counts Section IV's bits analytically; this module makes the
transport match the count.  A :class:`WirePayload` holds the real wire
arrays — uint32 bit-packed words plus f32 value/scale side streams — and
its :func:`payload_nbytes` is measured from the array shapes, so
``uplink_bits == 8 * nbytes`` holds by construction instead of by
formula.  The per-scheme encodings (word layout diagrams and the
analytic-vs-measured bits ledger: docs/wire.md):

* ``mask_shared`` (FedAdam-SSM family) — ONE support bitmap (1 bit per
  padded parameter slot) + three compacted f32 value streams of static
  capacity K (the worst-case mask population; unused tail slots are
  zero but still shipped — capacity must be static under jit).
* ``mask_independent`` (FedAdam-Top) — three (bitmap, value stream)
  pairs, one per tensor.
* ``sign`` (1-bit Adam, arXiv 2109.05109) — sign bitplane + one f32
  scale per 1024-element block.  Exact for ``quantize.sign_quant``
  carriers: each block is two-valued ``+-scale``.
* ``bbit`` (Efficient-Adam, arXiv 2205.02719) — b-bit offset codes
  (b in {2, 4, 8}) + the quantizer's per-block f32 scales.
* ``dense`` (FedAdam / FedSGD) — raveled f32 planes; measured bytes
  equal the analytic ``n_tensors * d * q`` exactly (no padding).

Layout reuses :class:`repro.core.sparsify.PackedLayout`: every leaf is
zero-padded to 1024 elements (so packed blocks align with the
quantizers' 1024-element scale blocks) and the concatenated buffer is
further padded to 4096 elements — the (32, 128) row-group granularity
of the ``kernels/wirepack`` word packers.  Padding slots cost wire bits
(they are honest transport overhead) and decode to values that the
shape-only ``layout.unpack`` slices away, so round-trips are exact.

Pack/unpack dispatches like every other hot path: Pallas kernels when
:func:`repro.core.sparsify.use_kernel_path` says so (TPU, or forced via
``REPRO_SPARSIFY_BACKEND``), bitwise-identical jnp references otherwise.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import sparsify as S
from repro.kernels.topk_mask.ops import overselect_bound
from repro.kernels.wirepack import ops as _wops
from repro.kernels.wirepack import ref as _wref
from repro.kernels.wirepack.wirepack import (
    CODE_SUBLANES, LANES, SUPPORTED_BITS, WORD_BITS)

_F32 = jnp.float32

#: Elements per f32 side-stream scale block == the packed layout's
#: per-leaf padding quantum, so buffer blocks ARE quantizer blocks.
SCALE_BLOCK = 1024
assert SCALE_BLOCK == S.PACK_BLOCK_ELEMS, \
    "wire scale blocks must match the packed-layout block size"

#: Word-packer row-group granularity: buffers are padded to a multiple
#: of 32 sublanes x 128 lanes so every (32, 128) code block maps to
#: whole uint32 word rows.
ALIGN_ELEMS = CODE_SUBLANES * LANES

#: All value/scale side streams ship as f32.
VALUE_BITS = 32


class WirePayload(NamedTuple):
    """A client's transported payload: the ONLY arrays that cross the
    client axis for wire-enabled schemes.

    ``words``  — uint32 bit-packed buffers (bitmaps / sign planes /
    b-bit codes); ``values`` — f32 value streams (compacted mask values
    or dense planes); ``scales`` — f32 per-block quantizer scales.  All
    three are tuples so the payload is a fixed-structure pytree that
    ``scan``/``vmap``/``shard_map`` can stack over clients."""
    words: Tuple[jax.Array, ...]
    values: Tuple[jax.Array, ...]
    scales: Tuple[jax.Array, ...]


def payload_nbytes(payload: WirePayload) -> int:
    """Measured payload size in bytes — from array shapes/dtypes (static
    under jit; works on tracers, which have no ``.nbytes``)."""
    return sum(int(a.size) * jnp.dtype(a.dtype).itemsize
               for part in payload for a in part)


# ---------------------------------------------------------------------------
# Static layout math (host ints — the accounting side of the format)
# ---------------------------------------------------------------------------


def padded_total(sizes: Sequence[int]) -> int:
    """Packed-buffer elements: each leaf padded to SCALE_BLOCK."""
    return sum(-(-int(n) // SCALE_BLOCK) * SCALE_BLOCK for n in sizes)


def aligned_total(sizes: Sequence[int]) -> int:
    """Word-packable elements: :func:`padded_total` padded to the
    (32, 128) row-group quantum."""
    t = padded_total(sizes)
    return -(-t // ALIGN_ELEMS) * ALIGN_ELEMS


def mask_value_capacity(sizes: Sequence[int], alpha: float,
                        mask_scope: str = "per_tensor",
                        exact_topk: bool = True) -> int:
    """Static worst-case population of one top-k mask over a tree with
    leaf ``sizes`` — the capacity of each compacted value stream.

    Mirrors the mask constructions in ``core/sparsify``: exact masks
    keep ``k_for`` per tensor (per-BLOCK for tensors above the blocked
    cutoff); threshold masks may overshoot by ``overselect_bound``."""
    def cap_exact(n: int) -> int:
        if n <= S.BLOCK:
            return min(n, S.k_for(n, alpha))
        nb = -(-n // S.BLOCK)
        return min(n, nb * S.k_for(S.BLOCK, alpha))

    def cap_thresh(n: int) -> int:
        k = S.k_for(n, alpha)
        return min(n, k + overselect_bound(k, n))

    cap = cap_exact if exact_topk else cap_thresh
    if mask_scope == "per_tensor":
        return sum(cap(int(n)) for n in sizes)
    return cap(int(sum(int(n) for n in sizes)))


def mask_wire_bits(sizes: Sequence[int], alpha: float,
                   mask_scope: str = "per_tensor",
                   exact_topk: bool = True, *, shared: bool = True) -> int:
    """Wire bits of one client's mask-scheme payload: bitmap (1 bit per
    aligned slot) + K f32 values per stream; one bitmap for the shared
    (SSM) layout, three for the independent (Top) layout."""
    t32 = aligned_total(sizes)
    cap = mask_value_capacity(sizes, alpha, mask_scope, exact_topk)
    if shared:
        return t32 + 3 * cap * VALUE_BITS
    return 3 * (t32 + cap * VALUE_BITS)


def sign_wire_bits(sizes: Sequence[int]) -> int:
    """1-bit Adam payload: sign bitplane + one f32 scale per block of
    the ALIGNED buffer (alignment blocks carry zero scales)."""
    t32 = aligned_total(sizes)
    return t32 + VALUE_BITS * (t32 // SCALE_BLOCK)


def bbit_wire_bits(sizes: Sequence[int], bits: int) -> int:
    """Efficient-Adam payload: b bits per aligned slot + the quantizer's
    per-block scales (one per UNALIGNED block — scales are per-leaf)."""
    t = padded_total(sizes)
    t32 = aligned_total(sizes)
    return bits * t32 + VALUE_BITS * (t // SCALE_BLOCK)


def dense_wire_bits(sizes: Sequence[int], n_tensors: int = 3) -> int:
    """Dense payload: raveled f32 planes, no padding — equals the
    analytic ``n_tensors * d * 32`` exactly."""
    return n_tensors * int(sum(int(n) for n in sizes)) * VALUE_BITS


# ---------------------------------------------------------------------------
# Backend dispatch + aligned-buffer plumbing
# ---------------------------------------------------------------------------


def _use_kernels() -> bool:
    return S.use_kernel_path()


def _pack_mask_bits(support):
    if _use_kernels():
        return _wops.pack_mask_bits(support)
    return _wref.pack_mask_bits_ref(support)


def _unpack_mask_bits(words):
    if _use_kernels():
        return _wops.unpack_mask_bits(words)
    return _wref.unpack_mask_bits_ref(words)


def _pack_sign_scale(xp):
    if _use_kernels():
        return _wops.pack_sign_scale(xp)
    return _wref.pack_sign_scale_ref(xp)


def _unpack_sign_scale(words, scales):
    if _use_kernels():
        return _wops.unpack_sign_scale(words, scales)
    return _wref.unpack_sign_scale_ref(words, scales)


def _pack_bbit(codes, bits):
    if _use_kernels():
        return _wops.pack_bbit(codes, bits)
    return _wref.pack_bbit_ref(codes, bits)


def _unpack_bbit(words, bits):
    if _use_kernels():
        return _wops.unpack_bbit(words, bits)
    return _wref.unpack_bbit_ref(words, bits)


def _layout_for(leaves) -> S.PackedLayout:
    return S.plan_packed_layout(leaves)


def _pack_aligned(layout: S.PackedLayout, leaves) -> jax.Array:
    """Leaves -> the ALIGNED (R32, 128) buffer (f32 unless told not)."""
    buf = layout.pack(leaves)
    rows = buf.shape[0]
    arows = -(-rows // CODE_SUBLANES) * CODE_SUBLANES
    if arows != rows:
        buf = jnp.pad(buf, ((0, arows - rows), (0, 0)))
    return buf


def _unpack_aligned(layout: S.PackedLayout, buf, like_leaves) -> list:
    """Aligned buffer -> leaves cast to the template dtypes (shape-only
    slicing; alignment and per-leaf padding discarded)."""
    rows = layout.total // S.PACK_LANES
    leaves = layout.unpack(buf[:rows])
    return [x.astype(t.dtype) for x, t in zip(leaves, like_leaves)]


def _f32_leaves(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [x.astype(_F32) for x in leaves], treedef


def _compact(flat_support, pos, buf, capacity: int) -> jax.Array:
    """Gather the supported entries of ``buf`` into the first
    ``count <= capacity`` slots of a static (capacity,) stream (slot
    ``capacity`` is the overflow drop slot; unused tail stays zero)."""
    flat = buf.reshape(-1).astype(_F32)
    idx = jnp.where(flat_support, pos, capacity)
    out = jnp.zeros((capacity + 1,), _F32).at[idx].set(flat, mode="drop")
    return out[:capacity]


def _expand(flat_support, pos, values, shape) -> jax.Array:
    """Inverse of :func:`_compact`: scatter the value stream back onto
    the support (capacity-overflow slots decode to zero)."""
    cap = values.shape[0]
    taken = jnp.take(values, jnp.clip(pos, 0, cap - 1))
    return jnp.where(flat_support & (pos < cap), taken,
                     jnp.zeros((), _F32)).reshape(shape)


def _support_positions(flat_support):
    """Rank of each supported slot in flat order (prefix-sum - 1)."""
    return jnp.cumsum(flat_support.astype(jnp.int32)) - 1


def pack_bits_1d(bits) -> jax.Array:
    """(n,) bool/int bitmap -> (ceil(n/32),) uint32, bit ``i`` of word
    ``w`` = slot ``32 w + i``.  Pure jnp on an arbitrary-length vector —
    usable inside shard_map MANUAL regions, where the tile-shaped Pallas
    word packers do not apply (device-local shards are 1-D and not
    (32, 128)-aligned).  Same little-endian-in-word convention as
    ``kernels/wirepack``."""
    n = bits.shape[0]
    nw = -(-n // WORD_BITS)
    b = jnp.pad(bits.astype(jnp.uint32), (0, nw * WORD_BITS - n))
    b = b.reshape(nw, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(jnp.left_shift(b, shifts[None, :]), axis=1,
                   dtype=jnp.uint32)


def unpack_bits_1d(words, n: int) -> jax.Array:
    """Inverse of :func:`pack_bits_1d`: (nw,) uint32 -> (n,) int32 in
    {0, 1} (word-padding tail sliced away)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = jnp.bitwise_and(
        jnp.right_shift(words[:, None], shifts[None, :]), jnp.uint32(1))
    return bits.reshape(-1)[:n].astype(jnp.int32)


# ---------------------------------------------------------------------------
# Scheme encoders/decoders
# ---------------------------------------------------------------------------


def pack_shared_mask(sW, sM, sV, capacity: int) -> WirePayload:
    """FedAdam-SSM wire: one bitmap of the UNION support of the three
    sparse carriers + three compacted value streams.

    The union is contained in the shared mask (so ``<= capacity``), and
    re-encoding a decoded triple reproduces the same union — packing is
    idempotent, which is what lets the async driver buffer payloads."""
    w_leaves, _ = _f32_leaves(sW)
    m_leaves, _ = _f32_leaves(sM)
    v_leaves, _ = _f32_leaves(sV)
    layout = _layout_for(w_leaves)
    wp = _pack_aligned(layout, w_leaves)
    mp = _pack_aligned(layout, m_leaves)
    vp = _pack_aligned(layout, v_leaves)
    support = (wp != 0) | (mp != 0) | (vp != 0)
    words = _pack_mask_bits(support.astype(jnp.int32))
    flat_sup = support.reshape(-1)
    pos = _support_positions(flat_sup)
    return WirePayload(
        words=(words,),
        values=(_compact(flat_sup, pos, wp, capacity),
                _compact(flat_sup, pos, mp, capacity),
                _compact(flat_sup, pos, vp, capacity)),
        scales=())


def unpack_shared_mask(payload: WirePayload, like):
    """Decode to the (sW, sM, sV) triple; ``like`` is any tree with the
    carrier's structure/shapes/dtypes (e.g. the params template)."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    layout = _layout_for(leaves)
    support = _unpack_mask_bits(payload.words[0])
    flat_sup = support.reshape(-1) == 1
    pos = _support_positions(flat_sup)
    outs = []
    for vals in payload.values:
        buf = _expand(flat_sup, pos, vals, support.shape)
        outs.append(jax.tree_util.tree_unflatten(
            treedef, _unpack_aligned(layout, buf, leaves)))
    return tuple(outs)


def pack_independent_mask(sW, sM, sV, capacity: int) -> WirePayload:
    """FedAdam-Top wire: three (bitmap, value stream) pairs — each
    tensor's own support."""
    words, values = [], []
    for tree in (sW, sM, sV):
        leaves, _ = _f32_leaves(tree)
        layout = _layout_for(leaves)
        xp = _pack_aligned(layout, leaves)
        support = xp != 0
        flat_sup = support.reshape(-1)
        pos = _support_positions(flat_sup)
        words.append(_pack_mask_bits(support.astype(jnp.int32)))
        values.append(_compact(flat_sup, pos, xp, capacity))
    return WirePayload(words=tuple(words), values=tuple(values), scales=())


def unpack_independent_mask(payload: WirePayload, like):
    leaves, treedef = jax.tree_util.tree_flatten(like)
    layout = _layout_for(leaves)
    outs = []
    for wrds, vals in zip(payload.words, payload.values):
        support = _unpack_mask_bits(wrds)
        flat_sup = support.reshape(-1) == 1
        pos = _support_positions(flat_sup)
        buf = _expand(flat_sup, pos, vals, support.shape)
        outs.append(jax.tree_util.tree_unflatten(
            treedef, _unpack_aligned(layout, buf, leaves)))
    return tuple(outs)


def pack_sign(carrier) -> WirePayload:
    """1-bit Adam wire: sign bitplane + per-block max-|.| scales of the
    aligned carrier buffer.  Exact for ``sign_quant`` carriers (every
    block is two-valued ``+-scale``; padding zeros never raise a max)."""
    leaves, _ = _f32_leaves(carrier)
    layout = _layout_for(leaves)
    xp = _pack_aligned(layout, leaves)
    words, scales = _pack_sign_scale(xp)
    return WirePayload(words=(words,), values=(), scales=(scales,))


def unpack_sign(payload: WirePayload, like):
    leaves, treedef = jax.tree_util.tree_flatten(like)
    layout = _layout_for(leaves)
    buf = _unpack_sign_scale(payload.words[0], payload.scales[0])
    return jax.tree_util.tree_unflatten(
        treedef, _unpack_aligned(layout, buf, leaves))


def pack_bbit_codes(codes_leaves, scales_leaves, bits: int) -> WirePayload:
    """Efficient-Adam wire: the quantizer's int32 codes word-packed at b
    bits (offset by qmax to unsigned; layout padding encodes code 0,
    i.e. offset qmax — decoded then sliced away) + per-leaf scales."""
    layout = _layout_for(codes_leaves)
    cp = _pack_aligned(layout, [c.astype(jnp.int32) for c in codes_leaves])
    words = _pack_bbit(cp, bits)
    return WirePayload(words=(words,), values=(),
                       scales=tuple(s.astype(_F32) for s in scales_leaves))


def unpack_bbit_codes(payload: WirePayload, like, bits: int):
    """Decode to the dequantized f32 carrier tree (``uniform_decode`` of
    each leaf's codes with its shipped scales)."""
    from repro.core import quantize
    leaves, treedef = jax.tree_util.tree_flatten(like)
    layout = _layout_for(leaves)
    cbuf = _unpack_bbit(payload.words[0], bits)
    rows = layout.total // S.PACK_LANES
    code_leaves = layout.unpack(cbuf[:rows])
    outs = [quantize.uniform_decode(c, s, SCALE_BLOCK).astype(t.dtype)
            for c, s, t in zip(code_leaves, payload.scales, leaves)]
    return jax.tree_util.tree_unflatten(treedef, outs)


def pack_dense(trees: Sequence[Any]) -> WirePayload:
    """FedAdam/FedSGD wire: one raveled f32 plane per communicated
    tensor — byte count equals the analytic formula exactly."""
    planes = tuple(
        jnp.concatenate([x.reshape(-1).astype(_F32)
                         for x in jax.tree_util.tree_leaves(t)])
        for t in trees)
    return WirePayload(words=(), values=planes, scales=())


def unpack_dense(payload: WirePayload, like):
    """Decode each plane back onto the ``like`` tree structure."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    outs = []
    for plane in payload.values:
        rebuilt, off = [], 0
        for t in leaves:
            rebuilt.append(plane[off:off + t.size]
                           .reshape(t.shape).astype(t.dtype))
            off += t.size
        outs.append(jax.tree_util.tree_unflatten(treedef, rebuilt))
    return tuple(outs)
