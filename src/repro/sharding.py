"""Logical-axis -> mesh-axis rules and deployment plans.

Meshes (launch/mesh.py):
  single-pod: (16, 16)      axes ("data", "model")
  multi-pod : (2, 16, 16)   axes ("pod", "data", "model")

Parameter rule-sets
-------------------
``tp``   : megatron-style tensor parallel — heads/mlp/experts/vocab over
           "model"; everything else replicated.  Used when one client's
           (or the serving) weights fit a 16-chip model group.
``fsdp`` : tp + the d_model ("embed") dimension sharded over the data(+pod)
           axes — fully-sharded storage with GSPMD inserting per-layer
           all-gathers.  Used for archs whose FedAdam state (6-7x weights)
           exceeds a 16-chip group: kimi-k2, jamba-1.5-large,
           mistral-large, gemma3-27b.

Client mappings (docs/ARCHITECTURE.md §3-§4):
``spatial`` : FL clients = mesh data(+pod) slices; per-client divergent
              replicas carried as a leading vmapped client axis.
``virtual`` : FL clients time-multiplexed by lax.scan; full mesh per client.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax import lax
from jax.sharding import PartitionSpec

from repro.configs.base import ArchConfig


def client_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def fsdp_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("data", "pod") if multi_pod else ("data",)


def param_rules(kind: str, multi_pod: bool) -> dict:
    rules = {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "embed": None,
        "kv_lora": None,
        "head_dim": None,
        "conv": None,
        "layers": None,
    }
    if kind == "fsdp":
        rules["embed"] = fsdp_axes(multi_pod)
    elif kind != "tp":
        raise ValueError(kind)
    return rules


def cache_rules(shape_kind: str, multi_pod: bool,
                cache_seq_shard=None) -> dict:
    """Logical rules for decode caches / activations-by-name.

    cache_seq_shard: optional mesh axis (or tuple) for the cache sequence
    dim — the split-KV decode optimization (kv_heads often cannot shard on
    a 16-way model axis: GQA kv=2..8, so the cache is otherwise replicated
    across "model" and dominates decode memory).
    """
    rules = {
        "batch": client_axes(multi_pod),
        "kv_heads": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "kv_lora": None,
        "kv_seq": None,
        "enc_seq": None,
        "head_dim": None,
        "ssm_state": None,
        "conv": None,
        "layers": None,
        "embed": None,
    }
    if shape_kind == "long":
        # batch=1: shard the cache sequence axis instead (split-KV decode)
        rules["batch"] = None
        rules["kv_seq"] = "data"
    if cache_seq_shard is not None:
        rules["kv_seq"] = cache_seq_shard
    return rules


# ---------------------------------------------------------------------------
# Deployment plans per architecture
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeployPlan:
    clients: str = "spatial"        # spatial | virtual
    train_params: str = "tp"        # tp | fsdp
    serve_params: str = "tp"        # tp | fsdp  (fsdp = "2D" for serving)
    n_virtual: int = 2              # virtual-client count in dry-run
    why: str = ""


_BIG = DeployPlan(
    clients="virtual", train_params="fsdp", serve_params="fsdp",
    why="FedAdam state (~7x weights) exceeds a 16-chip TP group; params "
        "fully sharded over (data[,pod],model), clients time-multiplexed")

_MID = DeployPlan(
    clients="virtual", train_params="fsdp", serve_params="tp",
    why="training state needs FSDP; serving weights fit a TP group")

PLANS = {
    "kimi-k2-1t-a32b": dataclasses.replace(
        _BIG, why=_BIG.why + "; 1T params — serving also needs 2D"),
    "jamba-1-5-large-398b": _BIG,
    "mistral-large-123b": _MID,
    "gemma3-27b": _MID,
    "deepseek-v2-lite-16b": DeployPlan(
        clients="spatial", train_params="tp", serve_params="tp",
        why="16B: per-client TP state ~14GB — spatial clients on the data "
            "axis exercise the full on-mesh sparse uplink"),
}

_DEFAULT = DeployPlan(why="small arch: spatial clients, TP within client")


def plan_for(arch: str) -> DeployPlan:
    return PLANS.get(arch, _DEFAULT)


# ---------------------------------------------------------------------------
# Activation sharding hint (used sparingly inside model code)
# ---------------------------------------------------------------------------


def hint(x, *axes):
    """with_sharding_constraint if a mesh is ambient, else identity."""
    try:
        return lax.with_sharding_constraint(x, PartitionSpec(*axes))
    except Exception:
        return x
