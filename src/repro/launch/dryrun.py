import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo with
ShapeDtypeStruct inputs (no allocation) and emit memory / cost / collective
analyses as JSON for the roofline table.

MUST be run as its own process (the XLA_FLAGS above lock the backend at
first jax init): one combo per invocation, e.g.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch mamba2-1-3b --shape decode_32k --mesh pod1 \
        --out experiments/dryrun/

or ``--all`` to iterate (slow; prefer the driver script
benchmarks/run_dryruns.sh which parallelizes across processes).
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro import compat
from repro import roofline as RL
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh


def run_one(arch: str, shape_name: str, mesh_name: str, out_dir: Path,
            **build_kw) -> dict:
    cfg = get_config(arch)
    shape = ST.SHAPES[shape_name]
    rec: dict = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                     status="ok")
    reason = ST.skip_reason(cfg, shape)
    if reason:
        rec.update(status="skip", reason=reason)
        return rec

    multi_pod = mesh_name == "pod2"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec["chips"] = chips

    bundle = ST.build_step(cfg, mesh, shape_name, **build_kw)
    t0 = time.time()
    with compat.set_mesh(mesh):
        jfn = compat.jit(bundle.fn,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jfn.lower(*bundle.args_sds)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = RL.collective_bytes(
        hlo, bundle.static.get("loop_trips", ()))

    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_rec[attr] = getattr(mem, attr, None)
    # peak per-device HBM = args + temps (aliased args are reused)
    args_b = mem_rec.get("argument_size_in_bytes") or 0
    temp_b = mem_rec.get("temp_size_in_bytes") or 0
    alias_b = mem_rec.get("alias_size_in_bytes") or 0
    out_b = mem_rec.get("output_size_in_bytes") or 0
    mem_rec["peak_per_device_bytes"] = args_b + temp_b + out_b - alias_b

    fed = bundle.static.get("fed")
    model_flops = RL.analytic_model_flops(
        cfg, shape.kind if shape.kind != "long" else "decode",
        shape.seq_len, shape.global_batch,
        local_epochs=(fed.local_epochs if fed else 1),
        n_virtual_clients=(bundle.static.get("n_clients", 1)
                           if fed and fed.client_mode == "scan" else 1))

    rec.update(
        t_lower_s=round(t_lower, 1),
        t_compile_s=round(t_compile, 1),
        memory=mem_rec,
        flops=cost.get("flops") if cost else None,
        bytes_accessed=cost.get("bytes accessed") if cost else None,
        collectives={k: v for k, v in coll.items()},
        model_flops=model_flops,
        n_params=cfg.param_count(),
        n_active=cfg.active_param_count(),
        plan=dataclass_str(bundle.static.get("plan")),
        hlo_lines=hlo.count("\n"),
    )
    # keep a trimmed HLO around for collective-schedule inspection
    out_dir.mkdir(parents=True, exist_ok=True)
    hlo_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.txt"
    keep = [ln for ln in hlo.splitlines()
            if any(c in ln for c in RL._COLLECTIVES) or ln.startswith("HloModule")]
    hlo_path.write_text("\n".join(keep))
    return rec


def dataclass_str(p) -> str:
    return str(p) if p is not None else ""


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(ST.SHAPES))
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--algorithm", default="fedadam_ssm")
    ap.add_argument("--aggregate", default=None)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--serve-params", default=None,
                    choices=[None, "tp", "fsdp"],
                    help="override the deploy plan's serving param rules")
    ap.add_argument("--cache-seq-shard", default=None,
                    help="mesh axis (or comma tuple) to shard decode cache "
                         "sequence dim — split-KV decode optimization")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out_dir = Path(args.out)
    combos = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in ST.SHAPES:
                combos.append((arch, shape, args.mesh))
    else:
        assert args.arch and args.shape
        combos.append((args.arch, args.shape, args.mesh))

    build_kw = {}
    rc = 0
    for arch, shape, mesh_name in combos:
        kw = dict(build_kw)
        if ST.SHAPES[shape].kind == "train":
            kw.update(algorithm=args.algorithm, alpha=args.alpha,
                      local_epochs=args.local_epochs, remat=args.remat)
            if args.aggregate:
                kw["aggregate"] = args.aggregate
        else:
            if args.cache_seq_shard:
                css = tuple(args.cache_seq_shard.split(","))
                kw["cache_seq_shard"] = css if len(css) > 1 else css[0]
            if args.serve_params:
                import dataclasses as _dc
                from repro.sharding import plan_for
                kw["plan"] = _dc.replace(plan_for(arch),
                                         serve_params=args.serve_params)
        name = f"{arch}__{shape}__{mesh_name}{args.tag}"
        try:
            rec = run_one(arch, shape, mesh_name, out_dir, **kw)
        except Exception as e:  # noqa: BLE001 — record the failure
            rec = dict(arch=arch, shape=shape, mesh=mesh_name,
                       status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
            rc = 1
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=1))
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"compile={rec['t_compile_s']}s "
                     f"coll={rec['collectives']['total']/1e9:.2f}GB "
                     f"mem/dev={rec['memory']['peak_per_device_bytes']/1e9:.2f}GB")
        elif status == "error":
            extra = rec["error"][:200]
        print(f"[dryrun] {name}: {status} {extra}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
