"""Serving driver: batched prefill + decode for any zoo architecture.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch mamba2-1-3b --smoke --batch 2 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data import synthetic_tokens, synthetic_frontend_embeds
from repro.models import (cache_meta, decode_step, init_params, materialize,
                          prefill)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)

    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(synthetic_tokens(args.batch, args.prompt_len,
                                        cfg.vocab_size, seed=0))
    kw = {}
    if cfg.stub_frontend:
        n_front = cfg.encoder.src_len if cfg.encoder is not None else \
            min(cfg.stub_frontend_tokens, 16)
        kw["frontend_embeds"] = jnp.asarray(
            synthetic_frontend_embeds(args.batch, n_front, cfg.d_model))

    seq_len = args.prompt_len + args.gen + \
        (0 if cfg.encoder is not None else
         (kw["frontend_embeds"].shape[1] if kw else 0))

    # prefill builds full-seq caches at prompt length; for the demo we use
    # the simpler decode-from-scratch path: replay the prompt through
    # decode_step (prefill output validated against it in tests).
    caches = materialize(cache_meta(cfg, args.batch, seq_len),
                         jax.random.PRNGKey(1))
    step = jax.jit(functools.partial(decode_step, cfg, seq_len=seq_len),
                   donate_argnums=(1,))

    t0 = time.time()
    pos = 0
    logits = None
    for i in range(args.prompt_len):
        logits, caches = step(params, caches, jnp.int32(pos), toks[:, i])
        pos += 1
    t_prefill = time.time() - t0

    out_tokens = []
    key = jax.random.PRNGKey(2)
    t0 = time.time()
    for i in range(args.gen):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / args.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        nxt = jnp.minimum(nxt, cfg.vocab_size - 1).astype(jnp.int32)
        out_tokens.append(np.asarray(nxt))
        logits, caches = step(params, caches, jnp.int32(pos), nxt)
        pos += 1
    t_gen = time.time() - t0

    out = np.stack(out_tokens, 1)
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"[serve] prompt replay {t_prefill:.2f}s, "
          f"decode {t_gen:.2f}s ({args.gen*args.batch/max(t_gen,1e-9):.1f} tok/s)")
    print("[serve] sample:", out[0][:12].tolist())


if __name__ == "__main__":
    main()
