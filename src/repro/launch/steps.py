"""Build jit-able train / prefill / serve steps for (arch x shape x mesh).

Every builder returns a ``StepBundle``: the python callable, example
``ShapeDtypeStruct`` arguments (no allocation) and the matching
in/out shardings — exactly what the dry-run lowers and what the real
launcher feeds with data.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ArchConfig
from repro.core.compressors import transport_of
from repro.core.fed import (
    FedConfig, FedState, client_state_pspecs, fed_init, make_fl_round,
)
from repro.models import model as M
from repro.models import params as PM
from repro.optim.adam import AdamHyper


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode | long


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "long"),
}


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    args_sds: Tuple[Any, ...]            # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    static: Dict[str, Any]               # bookkeeping for the roofline
    donate_argnums: Tuple[int, ...] = ()


def _axes_size(mesh, axes) -> int:
    return int(math.prod(mesh.shape[a] for a in axes))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _front_len(cfg: ArchConfig, seq_len: int) -> int:
    """Stub-frontend token budget within the sequence."""
    if cfg.encoder is not None:
        return cfg.encoder.src_len
    if cfg.stub_frontend:
        return min(cfg.stub_frontend_tokens, max(seq_len // 2, 16))
    return 0


def skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.kind == "long" and not cfg.supports_long_decode():
        if cfg.encoder is not None:
            return ("decoder positional capacity is 448 tokens by family "
                    "design — 500k decode is not a meaningful configuration")
        return ("pure full-attention family without a shipped sliding-window "
                "variant — 500k decode skipped per docs/ARCHITECTURE.md §6")
    return None



def _loop_trips(cfg: ArchConfig, kind: str, *, local_epochs: int = 1,
                n_virtual: int = 1, chunk: int = 1024,
                kv_len: int = 0) -> tuple:
    """Static scan-nesting trip counts, outermost first, used to scale
    collective bytes parsed from loop bodies (see roofline.collective_bytes)."""
    from repro.models.model import pattern_groups
    maxgroup = max(c for _, c in pattern_groups(cfg))
    chunks = max(1, kv_len // chunk)
    if kind == "train":
        lead = ([n_virtual] if n_virtual > 1 else []) + [local_epochs]
        return tuple(lead + [cfg.pattern_repeats, maxgroup, chunks])
    if kind == "prefill":
        return (cfg.pattern_repeats, maxgroup, chunks)
    return (cfg.pattern_repeats, maxgroup)


# ---------------------------------------------------------------------------
# Train step (one FL round)
# ---------------------------------------------------------------------------


def build_train_step(cfg: ArchConfig, mesh, shape: ShapeSpec, *,
                     algorithm: str = "fedadam_ssm", alpha: float = 0.05,
                     local_epochs: int = 2, remat: str = "full",
                     aggregate: Optional[str] = None,
                     plan: Optional[shd.DeployPlan] = None,
                     lr: float = 1e-3,
                     error_feedback: bool = False,
                     sparsify_backend: str = "auto",
                     participation: float = 1.0) -> StepBundle:
    multi_pod = "pod" in mesh.shape
    plan = plan or shd.plan_for(cfg.name)
    caxes = shd.client_axes(multi_pod)

    if plan.clients == "spatial":
        n_clients = _axes_size(mesh, caxes)
        client_mode = "vmap"
        if aggregate is None:
            # keyed on the compressor's transport tag: any registered
            # sparse scheme gets the packed all-gather uplink
            aggregate = ("sparse_gather"
                         if transport_of(algorithm) in
                         ("shared_sparse", "independent_sparse")
                         else "dense")
        per_client = max(1, shape.global_batch // n_clients)
        batch_lead = (n_clients, per_client)
        tok_spec = P(caxes if len(caxes) > 1 else caxes[0], None, None)
        emb_spec = P(caxes if len(caxes) > 1 else caxes[0], None, None, None)
    else:
        n_clients = plan.n_virtual
        client_mode = "scan"
        aggregate = aggregate or "dense"
        batch_lead = (n_clients, shape.global_batch)
        bax = caxes if len(caxes) > 1 else caxes[0]
        tok_spec = P(None, bax, None)
        emb_spec = P(None, bax, None, None)

    fed = FedConfig(
        algorithm=algorithm, alpha=alpha, local_epochs=local_epochs,
        n_clients=n_clients, adam=AdamHyper(lr=lr),
        client_mode=client_mode, aggregate=aggregate,
        # production masks: O(d) streaming threshold selection — on TPU
        # the backend dispatch (core/sparsify.resolve_backend) routes
        # these through the topk_mask + fused ssm_apply_ef Pallas
        # kernels; sort-based exact top-k is the small-model/test path
        exact_topk=False, mask_scope="per_tensor",
        sparsify_backend=sparsify_backend,
        error_feedback=error_feedback,
        # partial participation: fed.active_client_count drives both the
        # sync weight-masked sampling here and the async dispatch pool
        participation=participation,
        client_axes=(caxes if client_mode == "vmap" else None))

    n_front = _front_len(cfg, shape.seq_len)
    text_len = shape.seq_len - (n_front if cfg.encoder is None else 0)
    text_len = max(text_len, 32)

    def loss(params, batch):
        return M.loss_fn(cfg, params, batch["tokens"],
                         frontend_embeds=batch.get("embeds"),
                         remat=remat)

    # --- specs ---------------------------------------------------------
    meta = M.abstract_params(cfg)
    prules = shd.param_rules(plan.train_params, multi_pod)
    pspec = PM.pspecs(meta, prules, mesh)
    psds = PM.abstract(meta, cfg.dtype)

    sparse_agg = None
    if fed.client_mode == "vmap" and fed.aggregate == "sparse_gather":
        from repro.core.aggregate import make_shardmap_sparse_aggregate
        sparse_agg = make_shardmap_sparse_aggregate(
            mesh, pspec, caxes, alpha,
            shared=(transport_of(algorithm) == "shared_sparse"))

    round_fn = make_fl_round(fed, loss, sparse_aggregate_fn=sparse_agg)

    def train_step(state, batch):
        return round_fn(state, batch)

    # shape-only fed_init: stateful compressors (EF residuals, local_adam
    # moments) populate client_state with (C, *param)-shaped leaves; the
    # spec pins the client axis to the mesh client axes (spatial) or
    # leaves the virtual-client axis unsharded (scan), trailing dims
    # following the param sharding (core/fed.client_state_pspecs)
    state_sds = jax.eval_shape(lambda p: fed_init(fed, p), psds)
    cs_spec = client_state_pspecs(
        state_sds.client_state, pspec,
        caxes if client_mode == "vmap" else None)
    state_spec = FedState(W=pspec, M=pspec, V=pspec, round=P(),
                          client_state=cs_spec)

    batch_sds = {"tokens": _sds(batch_lead + (text_len,), jnp.int32)}
    batch_spec = {"tokens": tok_spec}
    if n_front:
        batch_sds["embeds"] = _sds(batch_lead + (n_front, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        batch_spec["embeds"] = emb_spec

    out_shardings = (state_spec, None)
    return StepBundle(
        fn=train_step,
        args_sds=(state_sds, batch_sds),
        in_shardings=(state_spec, batch_spec),
        out_shardings=out_shardings,
        static=dict(kind="train", n_clients=n_clients, plan=plan,
                    fed=fed, text_len=text_len, n_front=n_front,
                    loop_trips=_loop_trips(
                        cfg, "train", local_epochs=local_epochs,
                        n_virtual=(n_clients if client_mode == "scan" else 1),
                        kv_len=shape.seq_len)),
        donate_argnums=(0,),
    )


# ---------------------------------------------------------------------------
# Prefill step
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ArchConfig, mesh, shape: ShapeSpec, *,
                       plan: Optional[shd.DeployPlan] = None) -> StepBundle:
    multi_pod = "pod" in mesh.shape
    plan = plan or shd.plan_for(cfg.name)
    caxes = shd.client_axes(multi_pod)
    bax = caxes if len(caxes) > 1 else caxes[0]

    n_front = _front_len(cfg, shape.seq_len)
    text_len = shape.seq_len - (n_front if cfg.encoder is None else 0)
    text_len = max(text_len, 32)

    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch["tokens"],
                         frontend_embeds=batch.get("embeds"))

    meta = M.abstract_params(cfg)
    prules = shd.param_rules(plan.serve_params, multi_pod)
    pspec = PM.pspecs(meta, prules, mesh)
    psds = PM.abstract(meta, cfg.dtype)

    b = shape.global_batch
    batch_sds = {"tokens": _sds((b, text_len), jnp.int32)}
    batch_spec = {"tokens": P(bax, None)}
    if n_front:
        batch_sds["embeds"] = _sds((b, n_front, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        batch_spec["embeds"] = P(bax, None, None)

    return StepBundle(
        fn=prefill_step,
        args_sds=(psds, batch_sds),
        in_shardings=(pspec, batch_spec),
        out_shardings=None,
        static=dict(kind="prefill", plan=plan, text_len=text_len,
                    n_front=n_front,
                    loop_trips=_loop_trips(cfg, "prefill",
                                           kv_len=shape.seq_len)),
    )


# ---------------------------------------------------------------------------
# Serve (decode) step
# ---------------------------------------------------------------------------


def build_serve_step(cfg: ArchConfig, mesh, shape: ShapeSpec, *,
                     plan: Optional[shd.DeployPlan] = None,
                     cache_seq_shard=None) -> StepBundle:
    multi_pod = "pod" in mesh.shape
    plan = plan or shd.plan_for(cfg.name)
    long_mode = shape.kind == "long"
    caxes = shd.client_axes(multi_pod)
    bax = caxes if len(caxes) > 1 else caxes[0]

    b = shape.global_batch

    def serve_step(params, caches, pos, token):
        return M.decode_step(cfg, params, caches, pos, token,
                             seq_len=shape.seq_len, long_mode=long_mode)

    meta = M.abstract_params(cfg)
    prules = shd.param_rules(plan.serve_params, multi_pod)
    pspec = PM.pspecs(meta, prules, mesh)
    psds = PM.abstract(meta, cfg.dtype)

    cmeta = M.cache_meta(cfg, b, shape.seq_len, long_mode)
    crules = shd.cache_rules("long" if long_mode else "decode", multi_pod,
                             cache_seq_shard=cache_seq_shard)
    cspec = PM.pspecs(cmeta, crules, mesh)
    csds = PM.abstract(cmeta, cfg.dtype)

    tok_spec = P(None) if long_mode else P(bax)

    return StepBundle(
        fn=serve_step,
        args_sds=(psds, csds, _sds((), jnp.int32), _sds((b,), jnp.int32)),
        in_shardings=(pspec, cspec, P(), tok_spec),
        out_shardings=(None, cspec),
        static=dict(kind="long" if long_mode else "decode", plan=plan,
                    loop_trips=_loop_trips(cfg, "decode")),
        donate_argnums=(1,),
    )


def build_step(cfg: ArchConfig, mesh, shape_name: str, **kw) -> StepBundle:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_serve_step(cfg, mesh, shape, **kw)
