"""FL training driver: any zoo architecture x any FedAdam algorithm.

Runs for real on whatever devices exist (CPU here; the production mesh is
exercised via dryrun.py).  Examples:

    PYTHONPATH=src python -m repro.launch.train \
        --arch starcoder2-3b --smoke --rounds 5 --algorithm fedadam_ssm

    PYTHONPATH=src python -m repro.launch.train \
        --arch mamba2-1-3b --smoke --rounds 3 --algorithm fedadam_top
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_fed_state
from repro.configs import get_config, reduce_for_smoke
from repro.core import FedConfig, fed_init, make_compressor, make_fl_round
from repro.core.compressors import available as available_algorithms
from repro.data import synthetic_tokens, synthetic_frontend_embeds
from repro.models import init_params, loss_fn
from repro.optim import AdamHyper


def build_client_batches(cfg, n_clients, batch_size, seq_len, *, seed=0,
                         non_iid=True):
    toks = np.stack([
        synthetic_tokens(batch_size, seq_len, cfg.vocab_size, seed=seed,
                         topic=(c if non_iid else 0))
        for c in range(n_clients)])
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.stub_frontend:
        n_front = cfg.encoder.src_len if cfg.encoder is not None else \
            min(cfg.stub_frontend_tokens, 16)
        emb = np.stack([
            synthetic_frontend_embeds(batch_size, n_front, cfg.d_model,
                                      seed=seed + c)
            for c in range(n_clients)])
        batch["embeds"] = jnp.asarray(emb)
    return batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--algorithm", default="fedadam_ssm",
                    choices=available_algorithms(),
                    help="any registered compressor (docs/compressors.md)")
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-epochs", type=int, default=3)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--kernel-adam", action="store_true")
    ap.add_argument("--threshold-topk", action="store_true",
                    help="production O(d) threshold masks instead of "
                         "exact sort-based top-k")
    ap.add_argument("--sparsify-backend", default="auto",
                    choices=("auto", "kernel", "reference"),
                    help="threshold-mask implementation (docs/kernels.md; "
                         "kernel = Pallas, interpret mode off-TPU)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled per round (sync: "
                         "weight masking; async: dispatch pool)")
    # buffered-async mode (docs/async.md): K > 0 switches the driver
    ap.add_argument("--async-buffer", type=int, default=0, metavar="K",
                    help="server buffer size; 0 = synchronous round")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="discard updates staler than this at arrival")
    ap.add_argument("--staleness-power", type=float, default=0.5,
                    help="aggregation weight (1+s)**-power")
    ap.add_argument("--churn-seed", type=int, default=0)
    ap.add_argument("--churn-jitter", type=int, default=0)
    ap.add_argument("--churn-straggler-prob", type=float, default=0.0)
    ap.add_argument("--churn-drop-prob", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)

    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))

    fed = FedConfig(
        algorithm=args.algorithm, alpha=args.alpha,
        local_epochs=args.local_epochs, n_clients=args.clients,
        adam=AdamHyper(lr=args.lr), client_mode="scan",
        use_kernel_adam=args.kernel_adam,
        exact_topk=not args.threshold_topk,
        sparsify_backend=args.sparsify_backend,
        participation=args.participation)
    comp = make_compressor(fed)
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"{args.clients} clients, L={args.local_epochs}, "
          f"alpha={args.alpha}, algo={args.algorithm} "
          f"(transport={comp.transport}, "
          f"{comp.bits_per_client(n_params)/8e6:.2f} MB/client/round)")

    def loss(p, batch):
        return loss_fn(cfg, p, batch["tokens"],
                       frontend_embeds=batch.get("embeds"), remat="none")

    state = fed_init(fed, params)

    if args.async_buffer > 0:
        # buffered-async mode: one virtual-clock simulation covers all
        # rounds (server steps); clients re-train the same per-client
        # shards at every dispatch — docs/async.md
        from repro.core.async_fed import AsyncConfig, make_async_round
        from repro.data.churn import ChurnConfig, ChurnModel

        churn = ChurnModel(
            ChurnConfig(seed=args.churn_seed, jitter=args.churn_jitter,
                        straggler_prob=args.churn_straggler_prob,
                        drop_prob=args.churn_drop_prob),
            args.clients)
        acfg = AsyncConfig(buffer_size=args.async_buffer,
                           max_staleness=args.max_staleness,
                           staleness_power=args.staleness_power)
        run = make_async_round(fed, loss, acfg, churn=churn)
        batch = build_client_batches(cfg, args.clients, args.batch,
                                     args.seq, non_iid=not args.iid)
        t0 = time.time()
        state, mets = run(state, batch, rounds=args.rounds)
        for r, (loss_v, bits) in enumerate(zip(mets["loss_per_step"],
                                               mets["bits_per_step"])):
            print(f"[round {r:3d}] loss={loss_v:.4f} "
                  f"uplink={bits/8e6:.2f} MB")
        print(f"[train] async: {mets['server_steps']} server steps, "
              f"{mets['landed']} landed / {mets['dropped']} dropped / "
              f"{mets['discarded']} discarded, "
              f"total uplink={float(mets['uplink_bits'])/8e6:.2f} MB "
              f"({time.time()-t0:.1f}s)")
    else:
        round_fn = jax.jit(make_fl_round(fed, loss))
        for r in range(args.rounds):
            batch = build_client_batches(cfg, args.clients, args.batch,
                                         args.seq, seed=r,
                                         non_iid=not args.iid)
            t0 = time.time()
            state, mets = round_fn(state, batch)
            loss_v = float(jnp.mean(mets["loss"]))
            bits = float(mets["uplink_bits"])
            print(f"[round {r:3d}] loss={loss_v:.4f} "
                  f"uplink={bits/8e6:.2f} MB  ({time.time()-t0:.1f}s)")

    if args.checkpoint:
        save_fed_state(state, args.checkpoint,
                       meta=dict(arch=cfg.name, algorithm=args.algorithm,
                                 rounds=args.rounds))
        print(f"[train] saved {args.checkpoint}")


if __name__ == "__main__":
    main()
