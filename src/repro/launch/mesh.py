"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import and then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CPU integration tests (requires
    --xla_force_host_platform_device_count>=8 in the test process)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
