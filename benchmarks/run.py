"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # fast CPU suite
    PYTHONPATH=src python -m benchmarks.run --full     # larger models
    PYTHONPATH=src python -m benchmarks.run --json     # + BENCH_*.json

Prints ``name,us_per_call,derived`` CSV lines (plus per-benchmark CSV
artifacts under experiments/benchmarks/).  With ``--json``, the kernels
and compress suites additionally write the schema-versioned perf
trajectory artifacts ``BENCH_kernels.json`` / ``BENCH_compress.json`` to
the working directory (schema: docs/benchmarks.md; CI validates them via
``python -m benchmarks.common``).
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ("fig1", "fig2", "fig345", "kernels", "compress", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="bigger models / more rounds")
    ap.add_argument("--only", default=None,
                    help="comma list: " + ",".join(SUITES))
    ap.add_argument("--json", action="store_true",
                    help="emit BENCH_*.json artifacts (kernels, compress)")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else set(SUITES)
    unknown = want - set(SUITES)
    if unknown:
        ap.error(f"unknown suite(s) {sorted(unknown)}; known: {SUITES}")

    rows = []

    def emit(name, us, derived):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    if "fig1" in want:
        from benchmarks import fig1_delta_magnitudes as F1
        t0 = time.time()
        out = F1.run(width=0.5 if args.full else 0.25,
                     local_epochs=10 if args.full else 5)
        emit("fig1_delta_magnitudes", (time.time() - t0) * 1e6,
             f"ordering_dW>dM>dV={out['magnitude_ordering_holds']};"
             f"mean_log10={ {k: round(v, 2) for k, v in out['mean_log10'].items()} }")

    if "fig2" in want:
        from benchmarks import fig2_table1_acc_vs_comm as F2
        t0 = time.time()
        summary = F2.run(rounds=30 if args.full else 18,
                         width=0.5 if args.full else 0.25)
        ssm_iid = summary[("cnn", "iid", "fedadam_ssm")]
        dense_iid = summary[("cnn", "iid", "fedadam")]
        speedup = (dense_iid["comm_to_target_mbit"]
                   / max(ssm_iid["comm_to_target_mbit"], 1e-9))
        emit("fig2_table1_cnn", (time.time() - t0) * 1e6,
             f"ssm_final_acc={ssm_iid['final_acc']:.3f};"
             f"comm_speedup_vs_fedadam={speedup:.2f}x")

    if "fig345" in want:
        from benchmarks import fig345_sweeps as F3
        t0 = time.time()
        F3.run_L(rounds=12)
        F3.run_lr(rounds=12)
        final = F3.run_alpha(rounds=12)
        emit("fig345_sweeps", (time.time() - t0) * 1e6,
             f"alpha_final_accs={ {k: round(v, 3) for k, v in final.items()} }")

    if "kernels" in want:
        from benchmarks import kernel_bench as KB
        t0 = time.time()
        out = KB.run(json_out=args.json)
        emit("kernel_bench", (time.time() - t0) * 1e6,
             f"rows={len(out)} (see experiments/benchmarks/kernel_bench.csv)")

    if "compress" in want:
        from benchmarks import compress_bench as CB
        t0 = time.time()
        out = CB.run(json_out=args.json, full=args.full)
        emit("compress_bench", (time.time() - t0) * 1e6,
             f"rows={len(out)} "
             "(see experiments/benchmarks/compress_bench.csv)")

    if "roofline" in want:
        from benchmarks import roofline_table as RT
        t0 = time.time()
        out = RT.run()
        emit("roofline_table", (time.time() - t0) * 1e6,
             f"ok={out['n_ok']};skip={out['n_skip']};err={out['n_err']};"
             f"bottlenecks={out['bottlenecks']}")


if __name__ == "__main__":
    main()
