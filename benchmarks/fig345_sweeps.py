"""Figs. 3/4/5 reproduction: FedAdam-SSM sensitivity to local epochs L,
learning rate eta, and sparsification ratio alpha."""
from __future__ import annotations

from benchmarks.common import write_csv
from benchmarks.fl_vision import run_fl


def run_L(model="cnn", values=(1, 3, 10, 30), rounds=12, **kw):
    rows = []
    for L in values:
        res = run_fl(model, "fedadam_ssm", local_epochs=L, rounds=rounds,
                     **kw)
        for r, (l, a) in enumerate(zip(res.losses, res.accs)):
            rows.append((model, L, r, l, a))
    write_csv(f"fig3_{model}_local_epochs",
              ("model", "L", "round", "loss", "test_acc"), rows)
    return rows


def run_lr(model="cnn", values=(1e-4, 1e-3, 1e-2, 0.3), rounds=12, **kw):
    rows = []
    for lr in values:
        res = run_fl(model, "fedadam_ssm", lr=lr, rounds=rounds, **kw)
        for r, (l, a) in enumerate(zip(res.losses, res.accs)):
            rows.append((model, lr, r, l, a))
    write_csv(f"fig4_{model}_lr",
              ("model", "lr", "round", "loss", "test_acc"), rows)
    return rows


def run_alpha(model="cnn", values=(0.01, 0.05, 0.2, 1.0), rounds=12, **kw):
    rows = []
    final = {}
    for a in values:
        res = run_fl(model, "fedadam_ssm", alpha=a, rounds=rounds, **kw)
        for r, (l, ac) in enumerate(zip(res.losses, res.accs)):
            rows.append((model, a, r, l, ac))
        final[a] = res.accs[-1]
    write_csv(f"fig5_{model}_alpha",
              ("model", "alpha", "round", "loss", "test_acc"), rows)
    return final


if __name__ == "__main__":
    print("fig3:", run_L()[-1])
    print("fig4:", run_lr()[-1])
    print("fig5:", run_alpha())
