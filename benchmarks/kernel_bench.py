"""Kernel micro-benchmarks: us/call of the jnp reference paths at FL-client
scales (CPU timings; the Pallas kernels themselves are TPU-targeted and
interpret-mode timing is not meaningful — what we measure here is the
ALGORITHMIC win of threshold-selection over sort-based top-k, which holds
on any backend)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core import sparsify as S


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run(sizes=(1 << 16, 1 << 20, 1 << 23), alpha=0.05):
    rows = []
    for n in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        k = S.k_for(n, alpha)
        sort_fn = jax.jit(lambda v: S.topk_mask_exact(v, k))
        thr_fn = jax.jit(lambda v: S.topk_mask_threshold(v, k))
        t_sort = _time(sort_fn, x)
        t_thr = _time(thr_fn, x)
        rows.append(("topk_sort", n, f"{t_sort:.1f}", ""))
        rows.append(("topk_threshold", n, f"{t_thr:.1f}",
                     f"speedup={t_sort/t_thr:.2f}x"))
    write_csv("kernel_bench", ("name", "n", "us_per_call", "derived"), rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
