"""Kernel micro-benchmarks: us/call of the jnp reference paths at FL-client
scales (CPU timings; the Pallas kernels themselves are TPU-targeted and
interpret-mode timing is not meaningful — what we measure here is the
ALGORITHMIC win of threshold-selection over sort-based top-k and of the
packed cohort pipeline over the per-leaf loop, which holds on any
backend).  Selection *quality* (achieved-k vs requested k) is measured
through the 3-pass oracle ``select_tau_ref`` / the packed counts — a row
whose over-selection exceeds the kernel's published ``overselect_bound``
FAILS the run (raise, not a log line): the benchmark doubles as the
contract's regression gate.

Byte models come from ``repro.roofline`` (single source of truth shared
with the roofline projections — docs/benchmarks.md §4).

Row groups (BENCH_kernels.json):

* ``topk_sort`` / ``topk_threshold``       — per-leaf selection at flat n
* ``ssm_apply_ef_fused``                   — per-leaf fused apply at flat n
* ``packed_select`` / ``packed_apply_ef``  — the packed cohort kernels'
  scan-form oracles at flat n (single segment)
* ``compress_perleaf_<model>`` / ``compress_packed_<model>`` — END TO END
  compress of a real smoke pytree: the per-leaf loop (4 launches/leaf on
  TPU) vs the packed two-launch pipeline, same arithmetic, bit-identical
  outputs.  ``launches``/``leaves`` record the launch accounting.

``run(json_out=True)`` additionally emits the schema-versioned
``BENCH_kernels.json`` artifact (schema: docs/benchmarks.md, enforced by
``benchmarks.common.validate_bench``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row_builder, write_bench_json, write_csv
from repro.core import sparsify as S
from repro.kernels.packed_topk.ref import packed_apply_ef_ref, \
    packed_hist_ref, refine_taus
from repro.kernels.ssm_apply.ref import ssm_apply_ef_ref
from repro.kernels.topk_mask.ops import overselect_bound
from repro.kernels.topk_mask.ref import log2_taus, select_tau_ref
from repro.roofline import fused_apply_bytes, fused_compress_bytes, \
    packed_apply_bytes, packed_compress_bytes, packed_select_bytes, \
    selection_bytes

E2E_CONFIGS = ("whisper-base", "starcoder2-3b")


def _time(fn, *args, iters=5, best=False):
    # ONE warmup call (compile + first run); block on its full pytree.
    # (A previous version probed the output with isinstance(fn(*args), ..)
    # which invoked fn a second time during warmup.)  ``best=True`` takes
    # the minimum over iters instead of the mean — the standard noise
    # floor for the multi-ms end-to-end rows, whose CPU timings jitter
    # far more than the flat micro rows.
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return (min(ts) if best else sum(ts) / len(ts)) * 1e6


def _check_overselect(name: str, n: int, k: int, achieved: int):
    """Hard gate: a benchmark row violating the kernel's published
    over-selection bound fails the whole run — the bound is part of the
    selection contract (docs/kernels.md), not a soft metric."""
    bound = overselect_bound(k, n)
    if achieved - k > bound:
        raise RuntimeError(
            f"benchmark row {name!r}: achieved_k={achieved} exceeds "
            f"k={k} by {achieved - k} > overselect_bound={bound} (n={n})")


def _packed_flat_standins(x, k: int):
    """Single-segment packed pipeline over flat x, as the jit-able
    scan-form oracles (the CPU stand-in for the two TPU launches)."""
    layout = S.plan_packed_layout([x])
    seg_ids = layout.seg_ids
    ks = jnp.asarray([k], jnp.float32)
    ns = jnp.asarray([x.size], jnp.float32)

    def select(xp):
        am = jnp.max(jnp.abs(xp.astype(jnp.float32)))
        edges = log2_taus(am).reshape(1, -1)
        c1 = packed_hist_ref(xp, seg_ids, edges)
        return refine_taus(c1, edges, [am], ks)

    def apply_(taus2, wp, mp, vp):
        return packed_apply_ef_ref(taus2, seg_ids, ks, ns, (wp, mp, vp),
                                   value_dtype="bfloat16")

    return layout, select, apply_


def _tree_standins(tree, alpha: float):
    """End-to-end compress of a pytree under the ssm_w rule, both ways:
    the per-leaf loop (select + fused apply per leaf — 4 TPU launches
    each) and the packed cohort pipeline (2 launches total).  Both are
    the jnp oracles the kernels are tested bit-identical to, so this
    times the same arithmetic the TPU paths run."""
    leaves, _ = jax.tree_util.tree_flatten(tree)
    layout = S.plan_packed_layout(leaves)
    ks_list = [S.k_for(leaf.size, alpha) for leaf in leaves]
    ks = jnp.asarray(ks_list, jnp.float32)
    ns = jnp.asarray(layout.seg_sizes, jnp.float32)

    def perleaf(wl, ml, vl):
        out = []
        for w, m, v, k in zip(wl, ml, vl, ks_list):
            tau = select_tau_ref(w, k)
            out.append(ssm_apply_ef_ref(tau, w, m, v,
                                        value_dtype="bfloat16"))
        return out

    def packed(wl, ml, vl):
        wp, mp, vp = layout.pack(wl), layout.pack(ml), layout.pack(vl)
        absmax = [jnp.max(jnp.abs(w.astype(jnp.float32))) for w in wl]
        edges = jnp.stack([log2_taus(a) for a in absmax])
        c1 = packed_hist_ref(wp, layout.seg_ids, edges)
        taus2 = refine_taus(c1, edges, absmax, ks)
        outs = packed_apply_ef_ref(taus2, layout.seg_ids, ks, ns,
                                   (wp, mp, vp), value_dtype="bfloat16")
        return [layout.unpack(o) for o in outs[:4]] + [outs[-1]]

    return layout, perleaf, packed, ks_list


def _e2e_rows(add, alpha: float):
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import abstract_params, params as PM

    for cname in E2E_CONFIGS:
        cfg = reduce_for_smoke(get_config(cname))
        sds = PM.abstract(abstract_params(cfg), "float32")
        leaves, treedef = jax.tree_util.tree_flatten(sds)
        keys = jax.random.split(jax.random.PRNGKey(0),
                                3 * len(leaves)).reshape(3, len(leaves), 2)
        mk = lambda row, scale: [
            jax.random.normal(kk, l.shape, jnp.float32) * scale
            for kk, l in zip(row, leaves)]
        wl, ml = mk(keys[0], 1.0), mk(keys[1], 0.1)
        vl = [jnp.abs(v) for v in mk(keys[2], 0.01)]

        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        layout, perleaf, packed, ks_list = _tree_standins(tree, alpha)
        L = layout.num_leaves
        d = sum(layout.sizes)
        k = sum(ks_list)

        perleaf_fn = jax.jit(perleaf)
        packed_fn = jax.jit(packed)
        t_perleaf = _time(perleaf_fn, wl, ml, vl, iters=10, best=True)
        t_packed = _time(packed_fn, wl, ml, vl, iters=10, best=True)

        outs = packed_fn(wl, ml, vl)
        achieved = int(sum(float(c) for c in outs[-1][:, 0]))
        for leaf_k, leaf_n, cnt in zip(ks_list, layout.sizes,
                                       [float(c) for c in outs[-1][:, 0]]):
            _check_overselect(f"compress_packed_{cname}", leaf_n, leaf_k,
                              int(cnt))

        label = cname.replace("-", "_")
        add(f"compress_perleaf_{label}", d, t_perleaf, k=k,
            launches=4 * L, leaves=L,
            bytes_moved=sum(fused_compress_bytes(n)
                            for n in layout.sizes),
            speedup_vs_reference=1.0)
        add(f"compress_packed_{label}", d, t_packed,
            f"speedup={t_perleaf / t_packed:.2f}x", k=k,
            achieved_k=achieved, launches=2, leaves=L,
            bytes_moved=packed_compress_bytes(d),
            speedup_vs_reference=round(t_perleaf / t_packed, 3))


def run(sizes=(1 << 16, 1 << 20, 1 << 23), alpha=0.05, json_out=False):
    rows, jrows = [], []
    add = row_builder(rows, jrows)

    for n in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        k = S.k_for(n, alpha)
        sort_fn = jax.jit(lambda v: S.topk_mask_exact(v, k))
        thr_fn = jax.jit(lambda v: S.topk_mask_threshold(v, k))
        t_sort = _time(sort_fn, x)
        t_thr = _time(thr_fn, x)

        # selection quality of the kernel's 3-pass algorithm, via the
        # bit-identical jnp oracle (cheap at any n)
        tau = select_tau_ref(x, k)
        achieved = int(jnp.sum(jnp.abs(x) >= tau))
        over = (achieved - k) / k
        _check_overselect("topk_threshold", n, k, achieved)

        add("topk_sort", n, t_sort, k=k, speedup_vs_reference=1.0)
        add("topk_threshold", n, t_thr,
            f"speedup={t_sort / t_thr:.2f}x",
            k=k, achieved_k=achieved, overselect_frac=round(over, 5),
            bytes_moved=selection_bytes(n),
            gb_per_s=round(selection_bytes(n) / (t_thr * 1e-6) / 1e9, 3),
            speedup_vs_reference=round(t_sort / t_thr, 3))

        # fused compress arithmetic (what ssm_apply_ef streams in one
        # pass), timed as the composed jnp expression
        keys = jax.random.split(jax.random.PRNGKey(1), 2)
        dm, dv = (jax.random.normal(kk, (n,)) for kk in keys)
        fused_fn = jax.jit(lambda w, m, v: ssm_apply_ef_ref(
            tau, w, m, v, value_dtype="bfloat16"))
        t_fused = _time(fused_fn, x, dm, dv)
        add("ssm_apply_ef_fused", n, t_fused,
            bytes_moved=fused_apply_bytes(n),
            gb_per_s=round(fused_apply_bytes(n) / (t_fused * 1e-6) / 1e9,
                           3))

        # the packed cohort kernels' scan-form oracles (single segment):
        # launch 1 (histogram + host refine) and launch 2 (two-sweep
        # refine-count + tau-pick + apply)
        layout1, sel, app = _packed_flat_standins(x, k)
        xp = layout1.pack([x])
        wp, mp, vp = xp, layout1.pack([dm]), layout1.pack([dv])
        sel_fn = jax.jit(sel)
        t_psel = _time(sel_fn, xp)
        taus2 = sel_fn(xp)
        app_fn = jax.jit(app)
        t_papp = _time(app_fn, taus2, wp, mp, vp)
        pouts = app_fn(taus2, wp, mp, vp)
        pach = int(float(pouts[-1][0, 0]))
        _check_overselect("packed_apply_ef", n, k, pach)
        add("packed_select", n, t_psel, k=k,
            bytes_moved=packed_select_bytes(n),
            gb_per_s=round(packed_select_bytes(n) / (t_psel * 1e-6) / 1e9,
                           3),
            launches=1)
        add("packed_apply_ef", n, t_papp, k=k, achieved_k=pach,
            overselect_frac=round((pach - k) / k, 5),
            bytes_moved=packed_apply_bytes(n),
            gb_per_s=round(packed_apply_bytes(n) / (t_papp * 1e-6) / 1e9,
                           3),
            launches=1)

    _e2e_rows(add, alpha)

    write_csv("kernel_bench", ("name", "n", "us_per_call", "derived"), rows)
    if json_out:
        write_bench_json("kernels", jrows)
    return rows


if __name__ == "__main__":
    for r in run(json_out=True):
        print(",".join(str(c) for c in r))
