"""Kernel micro-benchmarks: us/call of the jnp reference paths at FL-client
scales (CPU timings; the Pallas kernels themselves are TPU-targeted and
interpret-mode timing is not meaningful — what we measure here is the
ALGORITHMIC win of threshold-selection over sort-based top-k, which holds
on any backend).  Selection *quality* (achieved-k vs requested k) is
measured through the 3-pass oracle ``select_tau_ref``, which the kernel
is asserted identical to in tests/test_kernels.py.

``run(json_out=True)`` additionally emits the schema-versioned
``BENCH_kernels.json`` artifact (schema: docs/benchmarks.md, enforced by
``benchmarks.common.validate_bench``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row_builder, write_bench_json, write_csv
from repro.core import sparsify as S
from repro.kernels.ssm_apply.ref import ssm_apply_ef_ref
from repro.kernels.topk_mask.ops import overselect_bound
from repro.kernels.topk_mask.ref import select_tau_ref


def _time(fn, *args, iters=5):
    # ONE warmup call (compile + first run); block on its full pytree.
    # (A previous version probed the output with isinstance(fn(*args), ..)
    # which invoked fn a second time during warmup.)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _selection_bytes(n: int, itemsize: int = 4) -> int:
    """Analytic HBM traffic of the 3-pass streaming selection: absmax +
    two count passes, each ONE read of x (docs/benchmarks.md §bytes)."""
    return 3 * n * itemsize


def _fused_apply_bytes(n: int, itemsize: int = 4) -> int:
    """Fused ssm_apply_ef: read dW/dM/dV once, write sW/sM/sV + residual
    (4th output) once — 3 reads + 4 writes."""
    return 7 * n * itemsize


def run(sizes=(1 << 16, 1 << 20, 1 << 23), alpha=0.05, json_out=False):
    rows, jrows = [], []
    add = row_builder(rows, jrows)

    for n in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        k = S.k_for(n, alpha)
        sort_fn = jax.jit(lambda v: S.topk_mask_exact(v, k))
        thr_fn = jax.jit(lambda v: S.topk_mask_threshold(v, k))
        t_sort = _time(sort_fn, x)
        t_thr = _time(thr_fn, x)

        # selection quality of the kernel's 3-pass algorithm, via the
        # bit-identical jnp oracle (cheap at any n)
        tau = select_tau_ref(x, k)
        achieved = int(jnp.sum(jnp.abs(x) >= tau))
        over = (achieved - k) / k
        assert achieved - k <= overselect_bound(k, n), (achieved, k)

        add("topk_sort", n, t_sort, k=k, speedup_vs_reference=1.0)
        add("topk_threshold", n, t_thr,
            f"speedup={t_sort / t_thr:.2f}x",
            k=k, achieved_k=achieved, overselect_frac=round(over, 5),
            bytes_moved=_selection_bytes(n),
            gb_per_s=round(_selection_bytes(n) / (t_thr * 1e-6) / 1e9, 3),
            speedup_vs_reference=round(t_sort / t_thr, 3))

        # fused compress arithmetic (what ssm_apply_ef streams in one
        # pass), timed as the composed jnp expression
        keys = jax.random.split(jax.random.PRNGKey(1), 2)
        dm, dv = (jax.random.normal(kk, (n,)) for kk in keys)
        fused_fn = jax.jit(lambda w, m, v: ssm_apply_ef_ref(
            tau, w, m, v, value_dtype="bfloat16"))
        t_fused = _time(fused_fn, x, dm, dv)
        add("ssm_apply_ef_fused", n, t_fused,
            bytes_moved=_fused_apply_bytes(n),
            gb_per_s=round(_fused_apply_bytes(n) / (t_fused * 1e-6) / 1e9,
                           3))

    write_csv("kernel_bench", ("name", "n", "us_per_call", "derived"), rows)
    if json_out:
        write_bench_json("kernels", jrows)
    return rows


if __name__ == "__main__":
    for r in run(json_out=True):
        print(",".join(str(c) for c in r))
