"""Kernel micro-benchmarks: us/call of the jnp reference paths at FL-client
scales (CPU timings; the Pallas kernels themselves are TPU-targeted and
interpret-mode timing is not meaningful — what we measure here is the
ALGORITHMIC win of threshold-selection over sort-based top-k and of the
packed cohort pipeline over the per-leaf loop, which holds on any
backend).  Selection *quality* (achieved-k vs requested k) is measured
through the 3-pass oracle ``select_tau_ref`` / the packed counts — a row
whose over-selection exceeds the kernel's published ``overselect_bound``
FAILS the run (raise, not a log line): the benchmark doubles as the
contract's regression gate.

Byte models come from ``repro.roofline`` (single source of truth shared
with the roofline projections — docs/benchmarks.md §4).

Row groups (BENCH_kernels.json):

* ``topk_sort`` / ``topk_threshold``       — per-leaf selection at flat n
* ``ssm_apply_ef_fused``                   — per-leaf fused apply at flat n
* ``packed_select`` / ``packed_apply_ef``  — the packed cohort kernels'
  scan-form oracles at flat n (single segment)
* ``compress_perleaf_<model>`` / ``compress_packed_<model>`` — END TO END
  compress of a real smoke pytree: the per-leaf loop (4 launches/leaf on
  TPU) vs the packed two-launch pipeline, same arithmetic, bit-identical
  outputs.  ``launches``/``leaves`` record the launch accounting.
* ``wirepack_*``                           — word-level wire encode/decode
  (the bit-packing the transport actually ships) at flat n
* ``uplink_bytes_dense_<model>`` / ``uplink_bytes_wire_<model>`` — the
  transported-bytes ledger on a real smoke pytree: dense f32 planes vs
  the measured WirePayload (``bytes_moved`` is the payload size; the
  wire row's ``speedup_vs_reference`` is the byte reduction).  A
  reduction below 8x at alpha=0.01 FAILS the run.

``run(json_out=True)`` additionally emits the schema-versioned
``BENCH_kernels.json`` artifact (schema: docs/benchmarks.md, enforced by
``benchmarks.common.validate_bench``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row_builder, write_bench_json, write_csv
from repro.core import sparsify as S
from repro.kernels.packed_topk.ref import packed_apply_ef_ref, \
    packed_hist_ref, refine_taus
from repro.kernels.ssm_apply.ref import ssm_apply_ef_ref
from repro.kernels.topk_mask.ops import overselect_bound
from repro.kernels.topk_mask.ref import log2_taus, select_tau_ref
from repro.kernels.wirepack.ref import pack_bbit_ref, pack_mask_bits_ref, \
    unpack_mask_bits_ref
from repro.roofline import fused_apply_bytes, fused_compress_bytes, \
    packed_apply_bytes, packed_compress_bytes, packed_select_bytes, \
    selection_bytes

E2E_CONFIGS = ("whisper-base", "starcoder2-3b")


def _time(fn, *args, iters=5, best=False):
    # ONE warmup call (compile + first run); block on its full pytree.
    # (A previous version probed the output with isinstance(fn(*args), ..)
    # which invoked fn a second time during warmup.)  ``best=True`` takes
    # the minimum over iters instead of the mean — the standard noise
    # floor for the multi-ms end-to-end rows, whose CPU timings jitter
    # far more than the flat micro rows.
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return (min(ts) if best else sum(ts) / len(ts)) * 1e6


def _check_overselect(name: str, n: int, k: int, achieved: int):
    """Hard gate: a benchmark row violating the kernel's published
    over-selection bound fails the whole run — the bound is part of the
    selection contract (docs/kernels.md), not a soft metric."""
    bound = overselect_bound(k, n)
    if achieved - k > bound:
        raise RuntimeError(
            f"benchmark row {name!r}: achieved_k={achieved} exceeds "
            f"k={k} by {achieved - k} > overselect_bound={bound} (n={n})")


def _packed_flat_standins(x, k: int):
    """Single-segment packed pipeline over flat x, as the jit-able
    scan-form oracles (the CPU stand-in for the two TPU launches)."""
    layout = S.plan_packed_layout([x])
    seg_ids = layout.seg_ids
    ks = jnp.asarray([k], jnp.float32)
    ns = jnp.asarray([x.size], jnp.float32)

    def select(xp):
        am = jnp.max(jnp.abs(xp.astype(jnp.float32)))
        edges = log2_taus(am).reshape(1, -1)
        c1 = packed_hist_ref(xp, seg_ids, edges)
        return refine_taus(c1, edges, [am], ks)

    def apply_(taus2, wp, mp, vp):
        return packed_apply_ef_ref(taus2, seg_ids, ks, ns, (wp, mp, vp),
                                   value_dtype="bfloat16")

    return layout, select, apply_


def _tree_standins(tree, alpha: float):
    """End-to-end compress of a pytree under the ssm_w rule, both ways:
    the per-leaf loop (select + fused apply per leaf — 4 TPU launches
    each) and the packed cohort pipeline (2 launches total).  Both are
    the jnp oracles the kernels are tested bit-identical to, so this
    times the same arithmetic the TPU paths run."""
    leaves, _ = jax.tree_util.tree_flatten(tree)
    layout = S.plan_packed_layout(leaves)
    ks_list = [S.k_for(leaf.size, alpha) for leaf in leaves]
    ks = jnp.asarray(ks_list, jnp.float32)
    ns = jnp.asarray(layout.seg_sizes, jnp.float32)

    def perleaf(wl, ml, vl):
        out = []
        for w, m, v, k in zip(wl, ml, vl, ks_list):
            tau = select_tau_ref(w, k)
            out.append(ssm_apply_ef_ref(tau, w, m, v,
                                        value_dtype="bfloat16"))
        return out

    def packed(wl, ml, vl):
        wp, mp, vp = layout.pack(wl), layout.pack(ml), layout.pack(vl)
        absmax = [jnp.max(jnp.abs(w.astype(jnp.float32))) for w in wl]
        edges = jnp.stack([log2_taus(a) for a in absmax])
        c1 = packed_hist_ref(wp, layout.seg_ids, edges)
        taus2 = refine_taus(c1, edges, absmax, ks)
        outs = packed_apply_ef_ref(taus2, layout.seg_ids, ks, ns,
                                   (wp, mp, vp), value_dtype="bfloat16")
        return [layout.unpack(o) for o in outs[:4]] + [outs[-1]]

    return layout, perleaf, packed, ks_list


def _e2e_rows(add, alpha: float):
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import abstract_params, params as PM

    for cname in E2E_CONFIGS:
        cfg = reduce_for_smoke(get_config(cname))
        sds = PM.abstract(abstract_params(cfg), "float32")
        leaves, treedef = jax.tree_util.tree_flatten(sds)
        keys = jax.random.split(jax.random.PRNGKey(0),
                                3 * len(leaves)).reshape(3, len(leaves), 2)
        mk = lambda row, scale: [
            jax.random.normal(kk, l.shape, jnp.float32) * scale
            for kk, l in zip(row, leaves)]
        wl, ml = mk(keys[0], 1.0), mk(keys[1], 0.1)
        vl = [jnp.abs(v) for v in mk(keys[2], 0.01)]

        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        layout, perleaf, packed, ks_list = _tree_standins(tree, alpha)
        L = layout.num_leaves
        d = sum(layout.sizes)
        k = sum(ks_list)

        perleaf_fn = jax.jit(perleaf)
        packed_fn = jax.jit(packed)
        t_perleaf = _time(perleaf_fn, wl, ml, vl, iters=10, best=True)
        t_packed = _time(packed_fn, wl, ml, vl, iters=10, best=True)

        outs = packed_fn(wl, ml, vl)
        achieved = int(sum(float(c) for c in outs[-1][:, 0]))
        for leaf_k, leaf_n, cnt in zip(ks_list, layout.sizes,
                                       [float(c) for c in outs[-1][:, 0]]):
            _check_overselect(f"compress_packed_{cname}", leaf_n, leaf_k,
                              int(cnt))

        label = cname.replace("-", "_")
        add(f"compress_perleaf_{label}", d, t_perleaf, k=k,
            launches=4 * L, leaves=L,
            bytes_moved=sum(fused_compress_bytes(n)
                            for n in layout.sizes),
            speedup_vs_reference=1.0)
        add(f"compress_packed_{label}", d, t_packed,
            f"speedup={t_perleaf / t_packed:.2f}x", k=k,
            achieved_k=achieved, launches=2, leaves=L,
            bytes_moved=packed_compress_bytes(d),
            speedup_vs_reference=round(t_perleaf / t_packed, 3))


def _wire_rows(add, alpha: float):
    """Transported-bytes ledger on the smoke pytrees: ravel-dense f32
    planes vs the WirePayload the SSM compressor actually ships.
    ``bytes_moved`` is MEASURED from the payload arrays (and cross-checked
    against the static layout math); us_per_call times the jitted encode.
    The >=8x byte reduction at alpha=0.01 is a hard gate — padding or
    capacity regressions in the wire layout fail the benchmark run."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.core import wire
    from repro.models import abstract_params, params as PM

    for cname in E2E_CONFIGS:
        cfg = reduce_for_smoke(get_config(cname))
        sds = PM.abstract(abstract_params(cfg), "float32")
        leaves, treedef = jax.tree_util.tree_flatten(sds)
        keys = jax.random.split(jax.random.PRNGKey(3),
                                3 * len(leaves)).reshape(3, len(leaves), 2)
        trees = [jax.tree_util.tree_unflatten(treedef, [
            jax.random.normal(kk, l.shape, jnp.float32)
            for kk, l in zip(row, leaves)]) for row in keys]
        mask = jax.tree_util.tree_unflatten(treedef, [
            S.topk_mask_exact(w, S.k_for(w.size, alpha))
            if w.size <= S.BLOCK else S.blocked_topk_mask(w, alpha)
            for w in jax.tree_util.tree_leaves(trees[0])])
        sW, sM, sV = (jax.tree_util.tree_map(
            lambda x, m: x * m, t, mask) for t in trees)

        sizes = tuple(l.size for l in leaves)
        d = sum(sizes)
        cap = wire.mask_value_capacity(sizes, alpha)

        dense_fn = jax.jit(lambda a, b, c: wire.pack_dense((a, b, c)))
        t_dense = _time(dense_fn, sW, sM, sV)
        dense_bytes = wire.payload_nbytes(dense_fn(sW, sM, sV))
        assert 8 * dense_bytes == wire.dense_wire_bits(sizes, 3)

        wire_fn = jax.jit(
            lambda a, b, c: wire.pack_shared_mask(a, b, c, cap))
        t_wire = _time(wire_fn, sW, sM, sV)
        wire_bytes = wire.payload_nbytes(wire_fn(sW, sM, sV))
        assert 8 * wire_bytes == wire.mask_wire_bits(sizes, alpha)

        ratio = dense_bytes / wire_bytes
        if ratio < 8.0:
            raise RuntimeError(
                f"uplink_bytes_wire_{cname}: {wire_bytes} B is only "
                f"{ratio:.2f}x below dense {dense_bytes} B "
                f"(alpha={alpha}; wire-format regression)")

        label = cname.replace("-", "_")
        add(f"uplink_bytes_dense_{label}", d, t_dense,
            bytes_moved=dense_bytes, speedup_vs_reference=1.0)
        add(f"uplink_bytes_wire_{label}", d, t_wire,
            f"reduction={ratio:.1f}x", bytes_moved=wire_bytes,
            speedup_vs_reference=round(ratio, 3))


def run(sizes=(1 << 16, 1 << 20, 1 << 23), alpha=0.05, json_out=False):
    rows, jrows = [], []
    add = row_builder(rows, jrows)

    for n in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        k = S.k_for(n, alpha)
        sort_fn = jax.jit(lambda v: S.topk_mask_exact(v, k))
        thr_fn = jax.jit(lambda v: S.topk_mask_threshold(v, k))
        t_sort = _time(sort_fn, x)
        t_thr = _time(thr_fn, x)

        # selection quality of the kernel's 3-pass algorithm, via the
        # bit-identical jnp oracle (cheap at any n)
        tau = select_tau_ref(x, k)
        achieved = int(jnp.sum(jnp.abs(x) >= tau))
        over = (achieved - k) / k
        _check_overselect("topk_threshold", n, k, achieved)

        add("topk_sort", n, t_sort, k=k, speedup_vs_reference=1.0)
        add("topk_threshold", n, t_thr,
            f"speedup={t_sort / t_thr:.2f}x",
            k=k, achieved_k=achieved, overselect_frac=round(over, 5),
            bytes_moved=selection_bytes(n),
            gb_per_s=round(selection_bytes(n) / (t_thr * 1e-6) / 1e9, 3),
            speedup_vs_reference=round(t_sort / t_thr, 3))

        # fused compress arithmetic (what ssm_apply_ef streams in one
        # pass), timed as the composed jnp expression
        keys = jax.random.split(jax.random.PRNGKey(1), 2)
        dm, dv = (jax.random.normal(kk, (n,)) for kk in keys)
        fused_fn = jax.jit(lambda w, m, v: ssm_apply_ef_ref(
            tau, w, m, v, value_dtype="bfloat16"))
        t_fused = _time(fused_fn, x, dm, dv)
        add("ssm_apply_ef_fused", n, t_fused,
            bytes_moved=fused_apply_bytes(n),
            gb_per_s=round(fused_apply_bytes(n) / (t_fused * 1e-6) / 1e9,
                           3))

        # the packed cohort kernels' scan-form oracles (single segment):
        # launch 1 (histogram + host refine) and launch 2 (two-sweep
        # refine-count + tau-pick + apply)
        layout1, sel, app = _packed_flat_standins(x, k)
        xp = layout1.pack([x])
        wp, mp, vp = xp, layout1.pack([dm]), layout1.pack([dv])
        sel_fn = jax.jit(sel)
        t_psel = _time(sel_fn, xp)
        taus2 = sel_fn(xp)
        app_fn = jax.jit(app)
        t_papp = _time(app_fn, taus2, wp, mp, vp)
        pouts = app_fn(taus2, wp, mp, vp)
        pach = int(float(pouts[-1][0, 0]))
        _check_overselect("packed_apply_ef", n, k, pach)
        add("packed_select", n, t_psel, k=k,
            bytes_moved=packed_select_bytes(n),
            gb_per_s=round(packed_select_bytes(n) / (t_psel * 1e-6) / 1e9,
                           3),
            launches=1)
        add("packed_apply_ef", n, t_papp, k=k, achieved_k=pach,
            overselect_frac=round((pach - k) / k, 5),
            bytes_moved=packed_apply_bytes(n),
            gb_per_s=round(packed_apply_bytes(n) / (t_papp * 1e-6) / 1e9,
                           3),
            launches=1)

        # word-level wire encode/decode (the ref oracles the Pallas
        # kernels are bitwise-tested against): bitmap pack/unpack and
        # 8-bit code pack over the (n/128, 128) aligned buffer
        sup = (jnp.abs(x) >= tau).astype(jnp.int32).reshape(-1, 128)
        pm_fn = jax.jit(pack_mask_bits_ref)
        t_pm = _time(pm_fn, sup)
        words = pm_fn(sup)
        um_fn = jax.jit(unpack_mask_bits_ref)
        t_um = _time(um_fn, words)
        codes = jax.random.randint(jax.random.PRNGKey(2), sup.shape,
                                   0, 256, jnp.int32)
        pb_fn = jax.jit(lambda c: pack_bbit_ref(c - 127, 8))
        t_pb = _time(pb_fn, codes)
        add("wirepack_pack_mask", n, t_pm, bytes_moved=4 * n + n // 8,
            gb_per_s=round((4 * n + n // 8) / (t_pm * 1e-6) / 1e9, 3))
        add("wirepack_unpack_mask", n, t_um, bytes_moved=4 * n + n // 8,
            gb_per_s=round((4 * n + n // 8) / (t_um * 1e-6) / 1e9, 3))
        add("wirepack_pack_bbit8", n, t_pb, bytes_moved=5 * n,
            gb_per_s=round(5 * n / (t_pb * 1e-6) / 1e9, 3))

    _e2e_rows(add, alpha)
    _wire_rows(add, alpha=0.01)

    write_csv("kernel_bench", ("name", "n", "us_per_call", "derived"), rows)
    if json_out:
        write_bench_json("kernels", jrows)
    return rows


if __name__ == "__main__":
    for r in run(json_out=True):
        print(",".join(str(c) for c in r))
