"""Compressor hot-path benchmark — the Top_k/SSM sparsification step that
the paper's entire communication win hinges on (BENCH_compress.json).

Measured per flat size (1<<16 .. 1<<23) and per real model pytree
(whisper-base / starcoder2-3b at ``reduce_for_smoke`` shapes — the full
configs do not fit a CPU testbed; on TPU the same harness runs the true
shapes):

* ``compress_sort``       — ``SharedTopKCompressor`` over the original
  sort-based exact masks (the default / small-model path; baseline for
  ``speedup_vs_reference``).
* ``compress_threshold``  — same compressor over the jnp
  threshold-bisection reference (``sparsify_backend="reference"``).
* ``compress_fused``      — the fused arithmetic the kernel backend
  streams in one pass (3-pass tau selection + ``ssm_apply_ef``: mask
  apply x3 + bf16 wire cast + EF residual), timed as the composed jnp
  expression.  Interpret-mode Pallas timing is meaningless off-TPU, so
  off-TPU this row measures the same arithmetic through XLA; on TPU it
  runs the real kernels.

``bytes_moved`` is the analytic HBM-traffic model of each variant,
imported from ``repro.roofline`` (single source shared with the roofline
projections — docs/benchmarks.md §4); ``achieved_k`` counts the
actually-kept support of the emitted payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row_builder, write_bench_json, write_csv
from benchmarks.kernel_bench import _time
from repro.core import sparsify as S
from repro.core.compressors.base import Deltas
from repro.core.compressors.topk import SharedTopKCompressor
from repro.kernels.ssm_apply.ref import ssm_apply_ef_ref
from repro.kernels.topk_mask.ref import select_tau_ref
from repro.roofline import composed_compress_bytes, fused_compress_bytes

CONFIG_NAMES = ("whisper-base", "starcoder2-3b")


def _deltas_for(tree) -> Deltas:
    key = jax.random.PRNGKey(0)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, 3 * len(leaves)).reshape(3, len(leaves), 2)
    mk = lambda row, scale: jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, l.shape, jnp.float32) * scale
                  for k, l in zip(row, leaves)])
    dW = mk(keys[0], 1.0)
    dM = mk(keys[1], 0.1)
    dV = jax.tree.map(jnp.abs, mk(keys[2], 0.01))
    return Deltas(dW, dM, dV)


def _compressor(exact: bool, alpha: float) -> SharedTopKCompressor:
    return SharedTopKCompressor(
        alpha=alpha, exact_topk=exact, error_feedback=True,
        value_dtype="bfloat16", sparsify_backend="reference")


def _time_compress(comp, deltas, iters) -> tuple:
    state = comp.init_state(deltas.W)
    fn = jax.jit(lambda dl, st: comp.compress(dl, st)[:2])
    us = _time(fn, deltas, state, iters=iters)
    packed, _ = fn(deltas, state)
    achieved = sum(int(jnp.sum(x != 0)) for x in jax.tree.leaves(packed.W))
    return us, achieved


def run(sizes=(1 << 16, 1 << 20, 1 << 23), alpha=0.05, json_out=False,
        full=False):
    rows, jrows = [], []
    add = row_builder(rows, jrows)

    def bench_tree(label, tree, iters):
        deltas = _deltas_for(tree)
        d = sum(x.size for x in jax.tree.leaves(tree))
        k = sum(S.k_for(x.size, alpha) for x in jax.tree.leaves(tree))
        t_sort, _ = _time_compress(_compressor(True, alpha), deltas, iters)
        t_thr, ach = _time_compress(_compressor(False, alpha), deltas,
                                    iters)
        # the fused-kernel arithmetic over the same pytree, one jit
        def fused(dl):
            out = []
            for w, m, v in zip(jax.tree.leaves(dl.W), jax.tree.leaves(dl.M),
                               jax.tree.leaves(dl.V)):
                tau = select_tau_ref(w, S.k_for(w.size, alpha))
                out.append(ssm_apply_ef_ref(tau, w, m, v,
                                            value_dtype="bfloat16"))
            return out
        t_fused = _time(jax.jit(fused), deltas, iters=iters)

        add(f"compress_sort{label}", d, t_sort, k=k,
            speedup_vs_reference=1.0)
        add(f"compress_threshold{label}", d, t_thr,
            f"speedup={t_sort / t_thr:.2f}x", k=k, achieved_k=ach,
            overselect_frac=round((ach - k) / k, 5),
            bytes_moved=composed_compress_bytes(d),
            speedup_vs_reference=round(t_sort / t_thr, 3))
        fused_note = ("" if jax.default_backend() == "tpu" else
                      "off-TPU stand-in: composed-jnp form of the kernel "
                      "arithmetic (oracle selection is O(32n) vectorized, "
                      "not streaming) — bytes_moved models the TPU kernel")
        add(f"compress_fused{label}", d, t_fused,
            f"speedup={t_sort / t_fused:.2f}x", k=k,
            bytes_moved=fused_compress_bytes(d),
            gb_per_s=round(fused_compress_bytes(d) / (t_fused * 1e-6) / 1e9,
                           3),
            speedup_vs_reference=round(t_sort / t_fused, 3),
            **({"note": fused_note} if fused_note else {}))

    for n in sizes:
        bench_tree("", {"w": jax.ShapeDtypeStruct((n,), jnp.float32)},
                   iters=5 if n <= 1 << 20 else 3)

    from repro.configs import get_config, reduce_for_smoke
    from repro.models import abstract_params, params as PM
    for name in CONFIG_NAMES:
        cfg = get_config(name)
        if not full:
            cfg = reduce_for_smoke(cfg)
        sds = PM.abstract(abstract_params(cfg), "float32")
        bench_tree(f"_{name.replace('-', '_')}", sds, iters=3)

    write_csv("compress_bench", ("name", "n", "us_per_call", "derived"),
              rows)
    if json_out:
        write_bench_json("compress", jrows)
    return rows


if __name__ == "__main__":
    for r in run(json_out=True):
        print(",".join(str(c) for c in r))
