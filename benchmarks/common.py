"""Shared benchmark harness utilities.

Every benchmark mirrors one paper table/figure, runs at CPU-feasible scale
(reduced widths / fewer rounds — the TREND is the reproduction target, the
absolute numbers belong to the paper's GPU testbed), and emits CSV rows.
"""
from __future__ import annotations

import csv
import time
from pathlib import Path
from typing import Iterable, Sequence

OUT_DIR = Path("experiments/benchmarks")


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
