"""Shared benchmark harness utilities.

Every benchmark mirrors one paper table/figure, runs at CPU-feasible scale
(reduced widths / fewer rounds — the TREND is the reproduction target, the
absolute numbers belong to the paper's GPU testbed), and emits CSV rows.

JSON artifacts: ``write_bench_json`` emits the schema-versioned
``BENCH_<name>.json`` perf-trajectory artifacts (``benchmarks.run
--json``), and ``validate_bench`` checks a parsed document against the
schema in docs/benchmarks.md.  CI runs the validator as
``python -m benchmarks.common BENCH_kernels.json ...`` — the schema the
docs describe and the schema CI enforces are this one module.
"""
from __future__ import annotations

import csv
import json
import sys
import time
from pathlib import Path
from typing import Iterable, List, Sequence

OUT_DIR = Path("experiments/benchmarks")

#: Bump when a field changes meaning or a required field is added;
#: documented in docs/benchmarks.md.
SCHEMA_VERSION = 1

#: Required top-level keys of a BENCH_*.json document.
TOP_KEYS = ("schema_version", "benchmark", "generated_by", "backend",
            "jax_version", "rows")

#: Required per-row fields -> type.  All other row fields are optional;
#: known optional numeric fields are listed in ROW_OPTIONAL.
ROW_REQUIRED = {"name": str, "n": int, "us_per_call": (int, float)}
ROW_OPTIONAL = {"dtype": str, "note": str,
                "bytes_moved": (int, float), "gb_per_s": (int, float),
                "k": int, "achieved_k": int,
                "overselect_frac": (int, float),
                "speedup_vs_reference": (int, float),
                # launch accounting of the packed cohort pipeline
                # (docs/kernels.md §4): Pallas launches per call and
                # pytree leaves covered by them
                "launches": int, "leaves": int}


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def row_builder(rows: list, jrows: list):
    """Shared row-shape builder for the JSON-emitting suites: appends
    the CSV tuple to ``rows`` and the schema'd dict to ``jrows``, so the
    BENCH_*.json row shape is defined once next to its schema."""
    def add(name, n, us, derived="", **extra):
        rows.append((name, n, f"{us:.1f}", derived))
        jrows.append({"name": name, "n": int(n),
                      "us_per_call": round(us, 2), "dtype": "float32",
                      **extra})
    return add


def write_bench_json(benchmark: str, rows: List[dict], out_dir=".") -> Path:
    """Emit ``BENCH_<benchmark>.json`` (schema in docs/benchmarks.md).
    Validates before writing so a malformed artifact can never ship."""
    import jax

    doc = {
        "schema_version": SCHEMA_VERSION,
        "benchmark": benchmark,
        "generated_by": "benchmarks.run",
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "rows": rows,
    }
    errors = validate_bench(doc)
    if errors:
        raise ValueError(f"BENCH_{benchmark}.json fails its own schema: "
                         + "; ".join(errors))
    path = Path(out_dir) / f"BENCH_{benchmark}.json"
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return path


def validate_bench(doc) -> List[str]:
    """Schema check of a parsed BENCH_*.json document; returns the list
    of violations (empty == valid)."""
    errors = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    for key in TOP_KEYS:
        if key not in doc:
            errors.append(f"missing top-level key {key!r}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"schema_version {doc.get('schema_version')!r} != "
                      f"{SCHEMA_VERSION}")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        errors.append("rows must be a non-empty list")
        return errors
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"rows[{i}] is not an object")
            continue
        for field, typ in ROW_REQUIRED.items():
            if field not in row:
                errors.append(f"rows[{i}] missing {field!r}")
            elif not isinstance(row[field], typ) \
                    or isinstance(row[field], bool):
                errors.append(f"rows[{i}].{field} has type "
                              f"{type(row[field]).__name__}")
        for field, typ in ROW_OPTIONAL.items():
            if field in row and (not isinstance(row[field], typ)
                                 or isinstance(row[field], bool)):
                errors.append(f"rows[{i}].{field} has type "
                              f"{type(row[field]).__name__}")
        if isinstance(row.get("us_per_call"), (int, float)) \
                and row["us_per_call"] < 0:
            errors.append(f"rows[{i}].us_per_call negative")
    return errors


def main(argv: Sequence[str]) -> int:
    """CLI validator: ``python -m benchmarks.common BENCH_*.json
    [--require name1,name2]``.

    ``--require`` fails validation unless every named row appears in the
    union of the validated documents' rows — CI uses it to pin the
    packed-pipeline rows so a refactor can't silently drop them."""
    if not argv:
        print("usage: python -m benchmarks.common BENCH_file.json ... "
              "[--require name1,name2]", file=sys.stderr)
        return 2
    required: List[str] = []
    files: List[str] = []
    it = iter(argv)
    for arg in it:
        if arg == "--require":
            val = next(it, "")
            required += [s for s in val.split(",") if s]
        elif arg.startswith("--require="):
            required += [s for s in arg.split("=", 1)[1].split(",") if s]
        else:
            files.append(arg)
    if not files:
        print("usage: python -m benchmarks.common BENCH_file.json ... "
              "[--require name1,name2]", file=sys.stderr)
        return 2
    bad = 0
    seen_names = set()
    for arg in files:
        path = Path(arg)
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[bench-schema] {path}: unreadable: {e}",
                  file=sys.stderr)
            bad += 1
            continue
        errors = validate_bench(doc)
        for e in errors:
            print(f"[bench-schema] {path}: {e}", file=sys.stderr)
        bad += bool(errors)
        rows = doc.get("rows") if isinstance(doc, dict) else None
        n_rows = len(rows) if isinstance(rows, list) else 0
        if isinstance(rows, list):
            seen_names |= {r.get("name") for r in rows
                           if isinstance(r, dict)}
        print(f"[bench-schema] {path}: "
              f"{'INVALID' if errors else 'ok'} ({n_rows} rows)")
    missing = [name for name in required if name not in seen_names]
    for name in missing:
        print(f"[bench-schema] required row {name!r} missing from "
              f"{', '.join(files)}", file=sys.stderr)
    if missing:
        bad += 1
    return 1 if bad else 0


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
