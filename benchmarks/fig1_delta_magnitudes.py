"""Fig. 1 reproduction: probability density of log10 |dW|, |dM|, |dV|.

The paper's claim: dW >> dM >> dV in magnitude (normal-ish in log space),
which justifies the Gamma-term dominance and hence SSM = Top_k(|dW|).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_csv
from repro.core import FedConfig, fed_init
from repro.core.fed import _local_adam, _tree_sub
from repro.data import iid_partition, synthetic_image_dataset, client_batches
from repro.models.vision import build_vision
from repro.optim import AdamHyper


def run(model: str = "cnn", rounds: int = 3, width: float = 0.25,
        local_epochs: int = 5):
    params, fwd, loss_fn, acc_fn, ds = build_vision(model, width=width)
    imgs, labels = synthetic_image_dataset(ds, 1024)
    parts = iid_partition(1024, 4)
    fed = FedConfig(algorithm="fedadam", alpha=1.0,
                    local_epochs=local_epochs, n_clients=4,
                    adam=AdamHyper(lr=1e-3))
    st = fed_init(fed, params)

    (bx, by), _ = client_batches([imgs, labels], parts, 32)
    batch = (jnp.asarray(bx[0]), jnp.asarray(by[0]))
    w, m, v, _ = _local_adam(loss_fn, st.W, st.M, st.V, batch, fed)
    dW = _tree_sub(w, st.W)
    dM = _tree_sub(m, st.M)
    dV = _tree_sub(v, st.V)

    rows = []
    stats = {}
    for name, tree in [("dW", dW), ("dM", dM), ("dV", dV)]:
        flat = jnp.concatenate([jnp.abs(x).reshape(-1)
                                for x in jax.tree.leaves(tree)])
        flat = flat[flat > 0]
        logs = jnp.log10(flat)
        stats[name] = float(jnp.mean(logs))
        hist, edges = np.histogram(np.asarray(logs), bins=40, density=True)
        for h, e in zip(hist, edges):
            rows.append((name, float(e), float(h)))
    write_csv("fig1_delta_magnitudes", ("tensor", "log10_mag", "density"),
              rows)
    ordered = stats["dW"] > stats["dM"] > stats["dV"]
    return dict(mean_log10=stats, magnitude_ordering_holds=bool(ordered))


if __name__ == "__main__":
    print(run())
