"""Fig. 2 + Table I reproduction: accuracy vs cumulative uplink for
FedAdam-SSM against all baselines, IID and non-IID.

CPU scale: reduced-width models + synthetic datasets; the deliverable is
the ORDERING (SSM best among sparse; sparse > quantized; IID > non-IID)
and the Table-I 'comm to target accuracy' ratios.
"""
from __future__ import annotations

from benchmarks.common import write_csv
from benchmarks.fl_vision import run_fl

ALGOS = ["fedadam_ssm", "fedadam_top", "fairness_top", "ssm_m", "ssm_v",
         "fedadam", "onebit_adam", "efficient_adam"]


def run(model: str = "cnn", rounds: int = 18, n_clients: int = 8,
        width: float = 0.25, target_frac: float = 0.9):
    rows = []
    summary = {}
    for setting, non_iid in [("iid", False), ("noniid", True)]:
        results = {}
        for algo in ALGOS:
            res = run_fl(model, algo, rounds=rounds, n_clients=n_clients,
                         width=width, non_iid=non_iid, local_epochs=3)
            results[algo] = res
            for r, (l, a, b) in enumerate(zip(res.losses, res.accs,
                                              res.cum_bits)):
                rows.append((model, setting, algo, r, l, a, b / 1e6))
        best_acc = max(max(r.accs) for r in results.values())
        target = target_frac * best_acc
        for algo, res in results.items():
            summary[(model, setting, algo)] = dict(
                final_acc=res.accs[-1],
                comm_to_target_mbit=res.comm_to_acc(target))
    write_csv(f"fig2_{model}_acc_vs_comm",
              ("model", "setting", "algorithm", "round", "loss",
               "test_acc", "cum_uplink_mbit"), rows)
    t1_rows = [(m, s, a, v["final_acc"], v["comm_to_target_mbit"])
               for (m, s, a), v in summary.items()]
    write_csv(f"table1_{model}",
              ("model", "setting", "algorithm", "final_acc",
               "comm_to_target_mbit"), t1_rows)
    return summary


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
