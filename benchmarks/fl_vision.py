"""Shared FL-on-vision runner for the paper's experiment suite
(Section VII: CNN/Fashion-MNIST, VGG-11/CIFAR-10, ResNet-18/SVHN)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedConfig, fed_init, make_fl_round
from repro.core.comm import bits_for
from repro.data import (client_batches, dirichlet_partition, iid_partition,
                        synthetic_image_dataset)
from repro.models.vision import build_vision
from repro.optim import AdamHyper


@dataclasses.dataclass
class RunResult:
    algorithm: str
    losses: List[float]
    accs: List[float]
    cum_bits: List[float]

    def comm_to_acc(self, target: float) -> float:
        """Minimum cumulative uplink (Mbit) to reach target accuracy —
        Table I's 'Comm.' column; inf if never reached."""
        for acc, bits in zip(self.accs, self.cum_bits):
            if acc >= target:
                return bits / 1e6
        return float("inf")


def run_fl(model: str = "cnn", algorithm: str = "fedadam_ssm", *,
           n_clients: int = 8, rounds: int = 15, local_epochs: int = 3,
           alpha: float = 0.05, lr: float = 1e-3, batch: int = 32,
           non_iid: bool = False, theta: float = 0.1, width: float = 0.25,
           n_train: int = 2048, n_test: int = 512, seed: int = 0,
           eval_every: int = 1, warmup_rounds: int = 2) -> RunResult:
    params, fwd, loss_fn, acc_fn, ds = build_vision(
        model, width=width, key=jax.random.PRNGKey(seed))
    imgs, labels = synthetic_image_dataset(ds, n_train + n_test, seed=seed)
    tr_x, tr_y = imgs[:n_train], labels[:n_train]
    te = (jnp.asarray(imgs[n_train:]), jnp.asarray(labels[n_train:]))
    if non_iid:
        parts = dirichlet_partition(tr_y, n_clients, theta, seed=seed)
    else:
        parts = iid_partition(n_train, n_clients, seed=seed)

    d = sum(x.size for x in jax.tree.leaves(params))

    def make(algo, a):
        fed = FedConfig(algorithm=algo, alpha=a, local_epochs=local_epochs,
                        n_clients=n_clients, adam=AdamHyper(lr=lr),
                        client_mode="scan")
        return fed, jax.jit(make_fl_round(fed, loss_fn))

    # 1-bit Adam two-phase: dense warmup populating V, then compressed
    two_phase = algorithm == "onebit_adam"
    fed, round_fn = make("fedadam" if two_phase else algorithm,
                         1.0 if algorithm in ("fedadam", "onebit_adam",
                                              "fedsgd", "efficient_adam")
                         else alpha)
    state = fed_init(fed, params)

    losses, accs, cum_bits = [], [], []
    total_bits = 0.0
    acc_eval = jax.jit(acc_fn)
    for r in range(rounds):
        if two_phase and r == warmup_rounds:
            fed, round_fn = make("onebit_adam", 1.0)
            st2 = fed_init(fed, state.W)
            state = st2._replace(M=state.M, V=state.V)
        (bx, by), weights = client_batches([tr_x, tr_y], parts, batch,
                                           seed=seed * 1000 + r)
        state, mets = round_fn(
            state, (jnp.asarray(bx), jnp.asarray(by)),
            jnp.asarray(weights))
        algo_now = ("fedadam" if (two_phase and r < warmup_rounds)
                    else algorithm)
        total_bits += bits_for(
            algo_now, d, max(1, int(round(alpha * d))), n_clients,
            warmup=(two_phase and r < warmup_rounds))
        losses.append(float(jnp.mean(mets["loss"])))
        if r % eval_every == 0 or r == rounds - 1:
            accs.append(float(acc_eval(state.W, te)))
        else:
            accs.append(accs[-1] if accs else 0.0)
        cum_bits.append(total_bits)
    return RunResult(algorithm, losses, accs, cum_bits)
