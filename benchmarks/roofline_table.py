"""Aggregate the dry-run JSON artifacts into the EXPERIMENTS.md §Roofline
table: three roofline terms per (arch x shape x mesh), dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import write_csv
from repro import roofline as RL

DRYRUN_DIR = Path("experiments/dryrun")


def load_rows(dryrun_dir: Path = DRYRUN_DIR):
    rows = []
    for p in sorted(dryrun_dir.glob("*.json")):
        if "_probe" in p.name or "__tag" in p.name:
            continue
        r = json.loads(p.read_text())
        if r.get("status") == "skip":
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             mesh=r["mesh"], status="skip",
                             reason=r.get("reason", "")))
            continue
        if r.get("status") != "ok":
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             mesh=r["mesh"], status="error",
                             reason=r.get("error", "")[:100]))
            continue
        chips = r["chips"]
        rl = RL.Roofline(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], chips=chips,
            hlo_flops=max(r.get("flops") or 0.0, r["model_flops"]),
            hlo_bytes=r.get("bytes_accessed") or 0.0,
            coll_bytes=r["collectives"]["total"],
            model_flops=r["model_flops"])
        row = rl.row()
        row.update(status="ok",
                   mem_per_dev_gb=r["memory"]["peak_per_device_bytes"] / 1e9,
                   hlo_flops_raw=r.get("flops"),
                   compile_s=r.get("t_compile_s"))
        rows.append(row)
    return rows


def run():
    rows = load_rows()
    header = ("arch", "shape", "mesh", "status", "t_compute_s", "t_memory_s",
              "t_collective_s", "bottleneck", "mem_per_dev_gb",
              "model_flops", "useful_ratio", "reason")
    out = []
    for r in rows:
        out.append(tuple(
            r.get(k, "") if not isinstance(r.get(k), float)
            else f"{r[k]:.4g}" for k in header))
    write_csv("roofline_table", header, out)
    ok = [r for r in rows if r.get("status") == "ok"]
    by_bottleneck = {}
    for r in ok:
        by_bottleneck.setdefault(r["bottleneck"], []).append(
            f"{r['arch']}/{r['shape']}/{r['mesh']}")
    return dict(n_ok=len(ok),
                n_skip=len([r for r in rows if r.get("status") == "skip"]),
                n_err=len([r for r in rows if r.get("status") == "error"]),
                bottlenecks={k: len(v) for k, v in by_bottleneck.items()})


def markdown_table(dryrun_dir: Path = DRYRUN_DIR) -> str:
    rows = load_rows(dryrun_dir)
    lines = ["| arch | shape | mesh | compute s | memory s | collective s "
             "| bottleneck | mem/dev GB | useful | note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                         f"— | — | {r['status'].upper()} | — | — | "
                         f"{r.get('reason','')[:80]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} "
            f"| {r['t_collective']:.2e} | **{r['bottleneck']}** "
            f"| {r['mem_per_dev_gb']:.2f} | {r['useful_ratio']:.2f} | |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(run())
    print(markdown_table())
