"""Quickstart: FedAdam-SSM vs dense FedAdam on a federated image task.

Runs in ~2 minutes on CPU.  Shows the public API end-to-end: build a model,
wrap any loss in the FL round, watch accuracy per uplink megabit.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import FedConfig, fed_init, make_fl_round
from repro.core.comm import bits_for
from repro.data import (client_batches, dirichlet_partition,
                        synthetic_image_dataset)
from repro.models.vision import build_vision
from repro.optim import AdamHyper


def main():
    # 1. a model + loss (any pytree-of-params callable works)
    params, fwd, loss_fn, acc_fn, ds = build_vision("cnn", width=0.25)
    d = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: CNN ({d/1e3:.0f}k params), dataset: synthetic {ds}")

    # 2. federated non-IID data (Dirichlet 0.1, the paper's setting)
    imgs, labels = synthetic_image_dataset(ds, 2048)
    parts = dirichlet_partition(labels[:1536], n_clients=8, theta=0.1)
    test = (jnp.asarray(imgs[1536:]), jnp.asarray(labels[1536:]))

    # 3. two optimizers: the paper's FedAdam-SSM and dense FedAdam
    for algo, alpha in [("fedadam_ssm", 0.05), ("fedadam", 1.0)]:
        fed = FedConfig(algorithm=algo, alpha=alpha, local_epochs=3,
                        n_clients=8, adam=AdamHyper(lr=1e-3))
        round_fn = jax.jit(make_fl_round(fed, loss_fn))
        state = fed_init(fed, params)
        bits_round = bits_for(algo, d, max(1, int(alpha * d)), 8)
        print(f"\n== {algo} (alpha={alpha}) — "
              f"{bits_round/8e6:.2f} MB uplink/round ==")
        total_mb = 0.0
        for r in range(10):
            (bx, by), w = client_batches([imgs[:1536], labels[:1536]],
                                         parts, 32, seed=r)
            state, mets = round_fn(state, (jnp.asarray(bx), jnp.asarray(by)),
                                   jnp.asarray(w))
            total_mb += bits_round / 8e6
            acc = float(acc_fn(state.W, test))
            print(f" round {r:2d} loss={float(jnp.mean(mets['loss'])):.4f} "
                  f"test_acc={acc:.3f} cum_uplink={total_mb:7.2f} MB")


if __name__ == "__main__":
    main()
