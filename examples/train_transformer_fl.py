"""End-to-end driver: federated training of a zoo transformer with
FedAdam-SSM for a few hundred rounds on synthetic non-IID token streams.

Default is a CPU-feasible reduced config of the assigned `starcoder2-3b`
family (~3M params); pass --steps/--width knobs for bigger runs on real
hardware.  This is the deliverable-(b) "train a model for a few hundred
steps" driver: every round = L local epochs x clients + sparse aggregation,
so 100 rounds x 3 epochs = 300 optimizer steps per client.

    PYTHONPATH=src python examples/train_transformer_fl.py --rounds 100
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_fed_state
from repro.configs import get_config, reduce_for_smoke
from repro.core import FedConfig, fed_init, make_fl_round
from repro.data import synthetic_tokens
from repro.models import init_params, loss_fn
from repro.optim import AdamHyper


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-epochs", type=int, default=3)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--algorithm", default="fedadam_ssm")
    ap.add_argument("--checkpoint", default="experiments/fl_transformer.npz")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (needs accelerators)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduce_for_smoke(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[fl-transformer] {cfg.name}: {n/1e6:.2f}M params, "
          f"{args.clients} clients, L={args.local_epochs}")

    fed = FedConfig(algorithm=args.algorithm, alpha=args.alpha,
                    local_epochs=args.local_epochs,
                    n_clients=args.clients, adam=AdamHyper(lr=1e-3))

    def loss(p, batch):
        return loss_fn(cfg, p, batch["tokens"], remat="none")

    round_fn = jax.jit(make_fl_round(fed, loss))
    state = fed_init(fed, params)

    t0 = time.time()
    for r in range(args.rounds):
        toks = jnp.stack([
            jnp.asarray(synthetic_tokens(args.batch, args.seq,
                                         cfg.vocab_size, seed=r, topic=c))
            for c in range(args.clients)])
        state, mets = round_fn(state, {"tokens": toks})
        if r % 10 == 0 or r == args.rounds - 1:
            print(f" round {r:4d} loss={float(jnp.mean(mets['loss'])):.4f} "
                  f"uplink={float(mets['uplink_bits'])/8e6:.2f} MB/round "
                  f"({time.time()-t0:.0f}s)")
    save_fed_state(state, args.checkpoint,
                   meta=dict(arch=cfg.name, rounds=args.rounds))
    print(f"[fl-transformer] checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
