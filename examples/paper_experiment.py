"""Reproduce the paper's Section-VII experiment protocol end-to-end on one
model/dataset pair: all baselines, IID + non-IID, accuracy-vs-communication
summary (Fig. 2 / Table I analog at CPU scale).

    PYTHONPATH=src python examples/paper_experiment.py --model cnn
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.fl_vision import run_fl  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="cnn",
                    choices=["cnn", "vgg11", "resnet18"])
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--non-iid", action="store_true")
    args = ap.parse_args()

    algos = ["fedadam_ssm", "fedadam_top", "fairness_top", "ssm_m",
             "ssm_v", "fedadam", "onebit_adam", "efficient_adam"]
    print(f"model={args.model} rounds={args.rounds} "
          f"{'non-IID(0.1)' if args.non_iid else 'IID'}")
    print(f"{'algorithm':16s} {'final_acc':>9s} {'MB/round':>9s} "
          f"{'MB to 90% best':>14s}")
    results = {}
    for algo in algos:
        res = run_fl(args.model, algo, rounds=args.rounds,
                     n_clients=args.clients, non_iid=args.non_iid)
        results[algo] = res
    best = max(max(r.accs) for r in results.values())
    for algo, res in results.items():
        mb_round = (res.cum_bits[0]) / 1e6 / 8
        print(f"{algo:16s} {res.accs[-1]:9.3f} {mb_round:9.2f} "
              f"{res.comm_to_acc(0.9 * best)/8:14.2f}")


if __name__ == "__main__":
    main()
