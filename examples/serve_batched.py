"""Batched serving example: prefill a batch of prompts, then decode with a
KV/SSM cache — exercises the same decode_step the production serve path
lowers in the dry-run.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-1-3b
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data import synthetic_tokens
from repro.models import cache_meta, decode_step, init_params, materialize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jnp.asarray(synthetic_tokens(args.batch, args.prompt_len,
                                           cfg.vocab_size))
    seq = args.prompt_len + args.gen
    caches = materialize(cache_meta(cfg, args.batch, seq),
                         jax.random.PRNGKey(1))
    step = jax.jit(functools.partial(decode_step, cfg, seq_len=seq),
                   donate_argnums=(1,))

    # prompt ingestion (teacher-forced decode; prefill() is the parallel
    # alternative validated against this in tests/test_decode_consistency)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, caches = step(params, caches, jnp.int32(i), prompts[:, i])
    print(f"[serve] prompt ingested in {time.time()-t0:.2f}s")

    t0 = time.time()
    toks = []
    for i in range(args.gen):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32) % cfg.vocab_size
        toks.append(np.asarray(nxt))
        logits, caches = step(params, caches,
                              jnp.int32(args.prompt_len + i), nxt)
    dt = time.time() - t0
    out = np.stack(toks, 1)
    print(f"[serve] {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s on CPU)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b][:16].tolist()}")


if __name__ == "__main__":
    main()
