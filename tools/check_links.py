#!/usr/bin/env python3
"""Check that relative markdown links in docs/ and *.md resolve.

Scans every ``*.md`` under the repo root (skipping dot-dirs) for inline
links ``[text](target)``; for each non-external target, verifies the
referenced file exists relative to the linking file (and that a
``#fragment`` on a local .md target matches a heading in it).  Exits
nonzero listing every dangling link.  Run from anywhere:

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".github", "node_modules", "__pycache__"}
EXTERNAL = ("http://", "https://", "mailto:")

#: The docs surface every PR must keep present (and thereby scanned):
#: rglob("*.md") only covers what exists, so a deleted doc would
#: otherwise silently shrink coverage.
REQUIRED_DOCS = (
    "README.md",
    "EXPERIMENTS.md",
    "docs/ARCHITECTURE.md",
    "docs/async.md",
    "docs/compressors.md",
    "docs/kernels.md",
    "docs/benchmarks.md",
    "docs/linting.md",
    "docs/wire.md",
)


def _squash(text: str) -> str:
    """Loose slug: lowercase alphanumerics only (GitHub's exact slug
    rules around dashes/symbols are fiddly; this catches truly dangling
    anchors without false-positiving on punctuation)."""
    return re.sub(r"[^a-z0-9]", "", text.lower())


def _headings(md: Path) -> set:
    slugs = set()
    for line in md.read_text(encoding="utf-8").splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if m:
            slugs.add(_squash(m.group(1)))
    return slugs


def check(root: Path) -> int:
    errors = []
    for rel in REQUIRED_DOCS:
        if not (root / rel).exists():
            errors.append(f"required doc missing: {rel}")
    md_files = [p for p in root.rglob("*.md")
                if not any(part in SKIP_DIRS or part.startswith(".")
                           for part in p.relative_to(root).parts[:-1])]
    for md in md_files:
        for target in LINK_RE.findall(md.read_text(encoding="utf-8")):
            if target.startswith(EXTERNAL):
                continue
            path_part, _, frag = target.partition("#")
            if not path_part:           # pure in-page anchor
                if frag and _squash(frag) not in _headings(md):
                    errors.append(f"{md.relative_to(root)}: dangling "
                                  f"anchor #{frag}")
                continue
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(root)}: broken link "
                              f"-> {target}")
            elif frag and dest.suffix == ".md" \
                    and _squash(frag) not in _headings(dest):
                errors.append(f"{md.relative_to(root)}: {path_part} has "
                              f"no heading for #{frag}")
    for e in errors:
        print(f"[check_links] {e}", file=sys.stderr)
    print(f"[check_links] {len(md_files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()))
