"""pallas-contract: BlockSpec tile alignment + per-launch VMEM budget.

Walks every ``pl.pallas_call`` site under ``src/repro/kernels/``,
resolves the block shapes of its in/out BlockSpecs (module constants like
``BLOCK = (SUBLANES, LANES)`` are folded; locally-bound ``spec = pl.
BlockSpec(...)`` names are chased within the enclosing function), and:

* flags any resolved block shape whose last two dims are not multiples of
  the float32 TPU tile ``(8, 128)`` (Mosaic pads misaligned tiles, which
  wastes VMEM and VPU lanes at best and fails to lower at worst);
* sums ``prod(block) * dtype_bytes`` over all specs — doubled for Pallas'
  double buffering — and flags launches whose estimate exceeds the VMEM
  budget (``--vmem-budget-mb``, default 16).

Unresolvable spec *counts* (``in_specs=[spec] * len(ins)``) fall back to
a documented fan-out of ``UNKNOWN_FANOUT`` specs so the estimate stays
conservative; unresolvable shapes are skipped (e.g. memory-space-only
specs).  Input dtypes are not statically known, so inputs are costed at
4 bytes (f32); output dtypes are read off the ``out_shape``
ShapeDtypeStructs when present.
"""
from __future__ import annotations

import ast
import math
from pathlib import Path
from typing import List, Optional, Tuple

from tools.lint.astutil import ConstEnv, dotted, last_segment, walk_own
from tools.lint.core import Context, Finding, rule

TILE = (8, 128)              # f32 min tile (sublanes, lanes)
UNKNOWN_FANOUT = 8           # spec count assumed for [spec] * len(xs)
DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4, "f32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2, "bf16": 2,
    "int8": 1, "uint8": 1, "bool": 1, "bool_": 1,
}


class _Spec:
    """One resolved BlockSpec: its block shape (or None) and the source
    line of the ``pl.BlockSpec(...)`` call for anchoring findings."""

    def __init__(self, shape: Optional[Tuple[int, ...]], line: int):
        self.shape = shape
        self.line = line


def _is_blockspec_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and last_segment(dotted(node.func)) == "BlockSpec")


def _spec_from_call(call: ast.Call, consts: ConstEnv) -> _Spec:
    shape_node = None
    if call.args:
        shape_node = call.args[0]
    for kw in call.keywords:
        if kw.arg == "block_shape":
            shape_node = kw.value
    shape = consts.eval(shape_node) if shape_node is not None else None
    if isinstance(shape, (int, float)):
        shape = (int(shape),)
    if isinstance(shape, tuple) and all(
            isinstance(s, int) and s > 0 for s in shape):
        return _Spec(tuple(int(s) for s in shape), call.lineno)
    return _Spec(None, call.lineno)


def _resolve_specs(node: ast.AST, consts: ConstEnv,
                   local_specs: dict) -> Tuple[List[_Spec], bool]:
    """-> (specs, count_known).  Handles inline BlockSpec calls, names
    bound to BlockSpecs, [E]*n / tuple([E]*n) replication, and (nested)
    list/tuple literals."""
    if _is_blockspec_call(node):
        return [_spec_from_call(node, consts)], True
    if isinstance(node, ast.Name) and node.id in local_specs:
        return [local_specs[node.id]], True
    if isinstance(node, (ast.List, ast.Tuple)):
        specs, known = [], True
        for elt in node.elts:
            sub, sub_known = _resolve_specs(elt, consts, local_specs)
            specs.extend(sub)
            known = known and sub_known
        return specs, known
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        seq = node.left if isinstance(node.left, (ast.List, ast.Tuple)) \
            else node.right
        count_node = node.right if seq is node.left else node.left
        if isinstance(seq, (ast.List, ast.Tuple)):
            base, _ = _resolve_specs(seq, consts, local_specs)
            count = consts.eval(count_node)
            if isinstance(count, int) and count >= 0:
                return base * count, True
            return base * UNKNOWN_FANOUT, False
    if isinstance(node, ast.Call) \
            and last_segment(dotted(node.func)) in ("tuple", "list") \
            and len(node.args) == 1:
        return _resolve_specs(node.args[0], consts, local_specs)
    return [], True


def _out_dtypes(node: Optional[ast.AST]) -> List[Optional[int]]:
    """Bytes-per-element for each ShapeDtypeStruct in out_shape, where
    statically readable."""
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        out = []
        for elt in node.elts:
            out.extend(_out_dtypes(elt))
        return out
    if isinstance(node, ast.Call):
        name = last_segment(dotted(node.func))
        if name == "ShapeDtypeStruct":
            dt = None
            if len(node.args) >= 2:
                dt = DTYPE_BYTES.get(
                    last_segment(dotted(node.args[1])) or "")
            return [dt]
    return [None]


def _misaligned(shape: Tuple[int, ...]) -> bool:
    if len(shape) >= 2:
        return shape[-1] % TILE[1] != 0 or shape[-2] % TILE[0] != 0
    return shape[-1] % TILE[1] != 0


def _check_call(ctx: Context, rel: str, fn: ast.FunctionDef,
                call: ast.Call, consts: ConstEnv, local_specs: dict,
                findings: List[Finding]) -> None:
    kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
    in_node = kwargs.get("in_specs")
    out_node = kwargs.get("out_specs")
    grid_spec = kwargs.get("grid_spec")
    if grid_spec is not None and isinstance(grid_spec, ast.Call):
        gkw = {kw.arg: kw.value for kw in grid_spec.keywords if kw.arg}
        in_node = in_node or gkw.get("in_specs")
        out_node = out_node or gkw.get("out_specs")

    groups = []
    approx = False
    for role, node in (("in_specs", in_node), ("out_specs", out_node)):
        if node is None:
            continue
        specs, known = _resolve_specs(node, consts, local_specs)
        approx = approx or not known
        groups.append((role, specs))

    out_bytes = _out_dtypes(kwargs.get("out_shape"))

    seen_lines = set()
    total = 0
    for role, specs in groups:
        for idx, spec in enumerate(specs):
            if spec.shape is None:
                continue
            if _misaligned(spec.shape) and spec.line not in seen_lines:
                seen_lines.add(spec.line)
                findings.append(Finding(
                    "pallas-contract", rel, spec.line,
                    f"{fn.name}: {role} block shape {spec.shape} is not "
                    f"aligned to the f32 TPU tile {TILE}"))
            bpe = 4
            if role == "out_specs" and idx < len(out_bytes) \
                    and out_bytes[idx]:
                bpe = out_bytes[idx]
            total += math.prod(spec.shape) * bpe
    total *= 2  # Pallas double-buffers HBM<->VMEM streams
    budget = int(ctx.vmem_budget_mb * 1024 * 1024)
    if total > budget:
        qual = "approx. " if approx else ""
        findings.append(Finding(
            "pallas-contract", rel, call.lineno,
            f"{fn.name}: {qual}per-launch VMEM estimate "
            f"{total // 1024} KiB exceeds the {ctx.vmem_budget_mb:g} MiB "
            f"budget"))


@rule("pallas-contract",
      "BlockSpec tile alignment and per-launch VMEM budget at every "
      "pl.pallas_call site under src/repro/kernels/")
def check(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    kdir = ctx.root / "src" / "repro" / "kernels"
    if not kdir.is_dir():
        return findings
    for path in sorted(kdir.rglob("*.py")):
        tree = ctx.tree(path)
        if tree is None:
            continue
        consts = ConstEnv()
        consts.load_module(tree)
        rel = ctx.rel(Path(path))
        for fn in [n for n in ast.walk(tree)
                   if isinstance(n, ast.FunctionDef)]:
            local_specs = {}
            for node in walk_own(fn):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and _is_blockspec_call(node.value)):
                    local_specs[node.targets[0].id] = _spec_from_call(
                        node.value, consts)
            for node in walk_own(fn):
                if (isinstance(node, ast.Call)
                        and last_segment(dotted(node.func))
                        == "pallas_call"):
                    _check_call(ctx, rel, fn, node, consts, local_specs,
                                findings)
    return findings
