"""jit-hazard: host-sync and recompile triggers inside traced code.

Builds a per-module call graph over the hot-path modules (``core/fed.py``,
``core/aggregate.py``, ``core/sparsify.py``, ``core/masks.py``,
``launch/steps.py``, ``core/compressors/*.py``), marks the traced roots,
and inside every function reachable from a root flags:

* ``int()`` / ``float()`` / ``bool()`` whose argument is not provably
  host-static (a traced operand concretizes -> TracerError, or silently
  device-syncs under jit disable);
* ``.item()`` / ``.tolist()`` (always a device sync);
* ``np.asarray`` / ``np.array`` on traced values (host transfer;
  ``jnp.asarray`` is fine and not flagged);
* Python ``if``/``while`` whose test numerically compares a function
  parameter that is not host-static (data-dependent control flow ->
  recompile per value or TracerBoolConversionError).

Traced roots per module: functions passed by name to
``jit``/``shard_map``/``scan``/``vmap``/... sites, jit-decorated
functions, and — mode-dependent — either every def nested directly in a
``make_*``/``build_*`` builder (fed.py, steps.py: the builders themselves
run at trace-build time and must NOT be flagged) or every module-level
def plus ``compress``/``decompress`` methods (aggregate, sparsify, masks,
compressors: the whole module body is round-function territory).

"Host-static" is a syntactic under-approximation: literals, ALL_CAPS
module constants, ``.shape``/``.size``/``.ndim``/``.dtype`` chains (and
subscripts of them), calls to a small whitelist of pure host functions
(``len``/``min``/``max``/``round``/``k_for``/``math.*``...) with static
arguments, and locals assigned from static expressions.  Anything else —
parameters included — is assumed traced.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set

from tools.lint.astutil import (dotted, last_segment, walk_own,
                                walk_statements)
from tools.lint.core import Context, Finding, rule

#: (relative path glob, root mode) — "builders" or "all_public"
SCAN_TARGETS = (
    ("src/repro/core/fed.py", "builders"),
    ("src/repro/core/async_fed.py", "builders"),
    ("src/repro/launch/steps.py", "builders"),
    ("src/repro/core/aggregate.py", "all_public"),
    ("src/repro/core/sparsify.py", "all_public"),
    ("src/repro/core/masks.py", "all_public"),
    ("src/repro/core/compressors/*.py", "all_public"),
)

TRACE_CALLS = {"jit", "shard_map", "scan", "vmap", "pmap", "fori_loop",
               "while_loop", "cond", "checkpoint", "remat"}
TRACED_METHODS = {"compress", "decompress", "bits_per_client"}
HOST_CASTS = {"int", "float", "bool"}
SYNC_METHODS = {"item", "tolist"}
NUMPY_HOST = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
STATIC_CALLS = {"len", "min", "max", "abs", "round", "int", "float",
                "sum", "prod", "ceil", "floor", "k_for", "pow",
                "overselect_bound"}
SHAPE_ATTRS = {"shape", "size", "ndim", "dtype", "itemsize"}


def _truncate(code: str, limit: int = 60) -> str:
    code = " ".join(code.split())
    return code if len(code) <= limit else code[:limit - 3] + "..."


class _Fn:
    def __init__(self, node: ast.FunctionDef, parent):
        self.node = node
        self.parent = parent          # _Fn, ast.ClassDef, or None (module)
        self.params = {a.arg for a in (node.args.args
                                       + node.args.posonlyargs
                                       + node.args.kwonlyargs)}


def _collect_fns(tree: ast.Module) -> List[_Fn]:
    out: List[_Fn] = []

    def visit(node, parent):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.FunctionDef):
                fn = _Fn(child, parent)
                out.append(fn)
                visit(child, fn)
            elif isinstance(child, ast.ClassDef):
                visit(child, child)
            else:
                visit(child, parent)

    visit(tree, None)
    return out


def _static_locals(fn: ast.FunctionDef) -> Set[str]:
    """Names assigned (in source order) from host-static expressions."""
    static: Set[str] = set()
    for stmt in walk_statements(fn.body):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            if _is_static(stmt.value, static):
                static.add(stmt.targets[0].id)
            else:
                static.discard(stmt.targets[0].id)
    return static


def _is_static(node: ast.AST, static: Set[str]) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id in static or node.id.isupper()
    if isinstance(node, ast.Attribute):
        if node.attr in SHAPE_ATTRS or node.attr.isupper():
            return True
        # self.<...> chains: instance config fields (dataclass hypers),
        # never traced arrays in this codebase's compressor protocol
        base = node.value
        while isinstance(base, ast.Attribute):
            base = base.value
        return isinstance(base, ast.Name) and base.id == "self"
    if isinstance(node, ast.Subscript):
        return _is_static(node.value, static)
    if isinstance(node, ast.Call):
        name = last_segment(dotted(node.func))
        return (name in STATIC_CALLS
                and all(_is_static(a, static) for a in node.args)
                and not node.keywords)
    if isinstance(node, (ast.BinOp,)):
        return _is_static(node.left, static) and \
            _is_static(node.right, static)
    if isinstance(node, ast.UnaryOp):
        return _is_static(node.operand, static)
    if isinstance(node, ast.Compare):
        return _is_static(node.left, static) and \
            all(_is_static(c, static) for c in node.comparators)
    if isinstance(node, ast.BoolOp):
        return all(_is_static(v, static) for v in node.values)
    if isinstance(node, ast.IfExp):
        return all(_is_static(n, static)
                   for n in (node.test, node.body, node.orelse))
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_static(e, static) for e in node.elts)
    if isinstance(node, (ast.GeneratorExp, ast.ListComp)):
        return _is_static(node.elt, static)
    return False


def _roots(fns: List[_Fn], mode: str) -> Set[ast.FunctionDef]:
    by_name: Dict[str, List[_Fn]] = {}
    for f in fns:
        by_name.setdefault(f.node.name, []).append(f)
    roots: Set[ast.FunctionDef] = set()

    for f in fns:
        node = f.node
        # jit-decorated (plain or functools.partial(jax.jit, ...))
        for dec in node.decorator_list:
            d = dotted(dec.func if isinstance(dec, ast.Call) else dec)
            if d and last_segment(d) in ("jit",):
                roots.add(node)
            if isinstance(dec, ast.Call) and last_segment(d) == "partial" \
                    and dec.args and last_segment(
                        dotted(dec.args[0])) == "jit":
                roots.add(node)
        # builders mode: defs nested directly inside make_*/build_*
        if mode == "builders" and isinstance(f.parent, _Fn) \
                and f.parent.parent is None \
                and f.parent.node.name.startswith(("make_", "build_")):
            roots.add(node)
        if mode == "all_public":
            if f.parent is None and not node.name.startswith("__"):
                roots.add(node)
            if isinstance(f.parent, ast.ClassDef) \
                    and node.name in TRACED_METHODS:
                roots.add(node)

    # functions handed by name to tracing transforms anywhere
    for f in fns:
        for call in walk_own(f.node):
            if not isinstance(call, ast.Call):
                continue
            if last_segment(dotted(call.func)) not in TRACE_CALLS:
                continue
            for arg in call.args[:2]:
                if isinstance(arg, ast.Name) and arg.id in by_name:
                    for cand in by_name[arg.id]:
                        roots.add(cand.node)
    return roots


def _reachable(fns: List[_Fn],
               roots: Set[ast.FunctionDef]) -> List[_Fn]:
    by_name: Dict[str, List[_Fn]] = {}
    by_node = {f.node: f for f in fns}
    for f in fns:
        by_name.setdefault(f.node.name, []).append(f)
    seen: Set[ast.FunctionDef] = set()
    stack = [by_node[r] for r in roots if r in by_node]
    while stack:
        f = stack.pop()
        if f.node in seen:
            continue
        seen.add(f.node)
        for call in walk_own(f.node):
            if not isinstance(call, ast.Call):
                continue
            callee = None
            if isinstance(call.func, ast.Name):
                callee = call.func.id
            elif isinstance(call.func, ast.Attribute) and isinstance(
                    call.func.value, ast.Name) \
                    and call.func.value.id == "self":
                callee = call.func.attr
            if callee and callee in by_name:
                stack.extend(by_name[callee])
    return [f for f in fns if f.node in seen]


def _check_fn(rel: str, f: _Fn, findings: List[Finding]) -> None:
    static = _static_locals(f.node)
    for node in walk_own(f.node):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            name = last_segment(d)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in SYNC_METHODS and not node.args:
                findings.append(Finding(
                    "jit-hazard", rel, node.lineno,
                    f"{f.node.name}: `.{node.func.attr}()` is a host "
                    f"sync inside traced code"))
            elif d in NUMPY_HOST:
                findings.append(Finding(
                    "jit-hazard", rel, node.lineno,
                    f"{f.node.name}: `{d}(...)` transfers a traced value "
                    f"to host (use jnp.*)"))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in HOST_CASTS and node.args \
                    and not all(_is_static(a, static) for a in node.args):
                snippet = _truncate(ast.unparse(node))
                findings.append(Finding(
                    "jit-hazard", rel, node.lineno,
                    f"{f.node.name}: host cast `{snippet}` on a value "
                    f"that is not provably static concretizes the "
                    f"tracer"))
        elif isinstance(node, (ast.If, ast.While)):
            test = node.test
            if not isinstance(test, ast.Compare):
                continue
            ops_ok = all(isinstance(o, (ast.Lt, ast.LtE, ast.Gt, ast.GtE,
                                        ast.Eq, ast.NotEq))
                         for o in test.ops)
            comparators = [test.left] + list(test.comparators)
            if any(isinstance(c, ast.Constant)
                   and isinstance(c.value, (str, type(None)))
                   for c in comparators):
                continue
            names = {n.id for n in ast.walk(test)
                     if isinstance(n, ast.Name)}
            if ops_ok and names & f.params \
                    and not _is_static(test, static):
                snippet = _truncate(ast.unparse(test))
                kind = "if" if isinstance(node, ast.If) else "while"
                findings.append(Finding(
                    "jit-hazard", rel, node.lineno,
                    f"{f.node.name}: Python `{kind} {snippet}:` on a "
                    f"parameter that is not provably static is a "
                    f"recompile/concretization hazard"))


@rule("jit-hazard",
      "host-sync and recompile triggers inside functions reachable from "
      "jit/shard_map roots in the hot-path modules")
def check(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for pattern, mode in SCAN_TARGETS:
        base = ctx.root
        paths = sorted(base.glob(pattern))
        for path in paths:
            tree = ctx.tree(path)
            if tree is None:
                continue
            rel = ctx.rel(Path(path))
            fns = _collect_fns(tree)
            roots = _roots(fns, mode)
            for f in _reachable(fns, roots):
                _check_fn(rel, f, findings)
    return findings
