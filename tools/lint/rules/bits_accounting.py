"""bits-accounting: registry / bits_per_client / docs-table drift.

The round's ``uplink_bits`` metric is produced by the active compressor's
``bits_per_client`` (core/fed.py), and ``docs/compressors.md`` carries
the per-scheme bit-formula table — three surfaces that historically
drift.  This rule parses ``src/repro/core/compressors/*.py`` (never
imports it) and checks:

* every ``register("<name>")`` call resolves to at least one concrete
  compressor class that defines — or inherits from a collected base —
  a *real* ``bits_per_client`` (a body that only ``raise``s, like the
  ``Compressor`` protocol stub, does not count);
* every public class deriving (transitively) from ``Compressor``
  defines or inherits a real ``bits_per_client``;
* the "Built-in algorithms" table in ``docs/compressors.md`` names
  exactly the set of registered algorithms — a registered name missing
  from the table, or a table row for an unregistered name, is an error;
* every registered compressor's ``compress`` (found through the base
  walk) builds a wire payload — a ``WirePayload`` construction, a
  ``pack_wire`` call, or a ``wire.pack_*`` builder call must appear in
  the body, so a new scheme cannot ship dense bytes while reporting
  compressed bits (docs/wire.md);
* a class-level ``block`` literal must equal ``wire.SCALE_BLOCK`` (read
  from ``src/repro/core/wire.py``, 1024) — an off-contract quantizer
  block silently misaligns the payload's per-block scale stream.

Registration is recognized both as a decorator (``@register("x")``) and
as a direct call (``register("x")(factory(...))``); the factory body is
walked for class instantiations to bind name -> class.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from tools.lint.astutil import dotted, last_segment
from tools.lint.core import Context, Finding, rule

DOCS = "docs/compressors.md"
TABLE_HEADING = "built-in algorithms"
NAME_RE = re.compile(r"^[a-z0-9_]+$")


class _Class:
    def __init__(self, node: ast.ClassDef, rel: str):
        self.node = node
        self.rel = rel
        self.bases = [last_segment(dotted(b)) for b in node.bases]
        self.methods = {n.name: n for n in node.body
                        if isinstance(n, ast.FunctionDef)}


def _pure_raise(fn: ast.FunctionDef) -> bool:
    body = fn.body
    if body and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Constant) \
            and isinstance(body[0].value.value, str):
        body = body[1:]
    return len(body) == 1 and isinstance(body[0], ast.Raise)


def _defines_real_bits(name: str, classes: Dict[str, _Class],
                       seen: Optional[Set[str]] = None) -> bool:
    seen = seen or set()
    if name in seen or name not in classes:
        return False
    seen.add(name)
    cls = classes[name]
    fn = cls.methods.get("bits_per_client")
    if fn is not None:
        return not _pure_raise(fn)
    return any(_defines_real_bits(b, classes, seen)
               for b in cls.bases if b)


def _derives_from_compressor(name: str, classes: Dict[str, _Class],
                             seen: Optional[Set[str]] = None) -> bool:
    seen = seen or set()
    if name in seen or name not in classes:
        return False
    seen.add(name)
    for b in classes[name].bases:
        if b == "Compressor" or (b and _derives_from_compressor(
                b, classes, seen)):
            return True
    return False


def _find_method(name: str, mname: str, classes: Dict[str, _Class],
                 seen: Optional[Set[str]] = None
                 ) -> Optional[ast.FunctionDef]:
    """The method a class would inherit: own def first, then bases."""
    seen = seen or set()
    if name in seen or name not in classes:
        return None
    seen.add(name)
    cls = classes[name]
    if mname in cls.methods:
        return cls.methods[mname]
    for b in cls.bases:
        fn = _find_method(b, mname, classes, seen) if b else None
        if fn is not None:
            return fn
    return None


def _builds_payload(fn: ast.FunctionDef) -> bool:
    """True if the body contains a wire-payload construction: a
    ``WirePayload(...)`` call, any ``*pack_wire(...)`` call, or a
    ``wire.pack_*(...)`` builder call."""
    for call in ast.walk(fn):
        if not isinstance(call, ast.Call):
            continue
        path = dotted(call.func) or ""
        name = last_segment(path)
        if name == "WirePayload" or name.endswith("pack_wire") \
                or path.startswith("wire.pack"):
            return True
    return False


def _scale_block(ctx: Context) -> int:
    """``wire.SCALE_BLOCK`` read from the AST (fallback 1024)."""
    tree = ctx.tree(ctx.root / "src" / "repro" / "core" / "wire.py")
    if tree is not None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "SCALE_BLOCK"
                    for t in node.targets) \
                    and isinstance(node.value, ast.Constant) \
                    and isinstance(node.value.value, int):
                return node.value.value
    return 1024


def _block_literal(cls: _Class) -> Optional[Tuple[int, int]]:
    """(value, line) of a class-level ``block = <int>`` literal."""
    for stmt in cls.node.body:
        if isinstance(stmt, ast.AnnAssign) \
                and isinstance(stmt.target, ast.Name) \
                and stmt.target.id == "block" \
                and isinstance(stmt.value, ast.Constant) \
                and isinstance(stmt.value.value, int):
            return stmt.value.value, stmt.lineno
        if isinstance(stmt, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "block"
                for t in stmt.targets) \
                and isinstance(stmt.value, ast.Constant) \
                and isinstance(stmt.value.value, int):
            return stmt.value.value, stmt.lineno
    return None


def _instantiated_classes(node: ast.AST,
                          classes: Dict[str, _Class]) -> Set[str]:
    out = set()
    for call in ast.walk(node):
        if isinstance(call, ast.Call):
            name = last_segment(dotted(call.func))
            if name in classes:
                out.add(name)
    return out


def _doc_table(ctx: Context) -> List[Tuple[str, int]]:
    """(algorithm name, line) for each row of the built-in table."""
    src = ctx.source(ctx.root / DOCS)
    rows: List[Tuple[str, int]] = []
    if src is None:
        return rows
    in_section = False
    for i, line in enumerate(src.splitlines(), start=1):
        if line.startswith("#"):
            in_section = TABLE_HEADING in line.lower()
            continue
        if not in_section or not line.lstrip().startswith("|"):
            continue
        first = line.split("|")[1].strip().strip("`")
        if NAME_RE.match(first):
            rows.append((first, i))
    return rows


@rule("bits-accounting",
      "registered compressors define bits_per_client and the "
      "docs/compressors.md table names exactly the registry")
def check(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    pkg = ctx.root / "src" / "repro" / "core" / "compressors"
    if not pkg.is_dir():
        return findings

    classes: Dict[str, _Class] = {}
    factories: Dict[str, ast.FunctionDef] = {}
    registered: Dict[str, Tuple[str, int, Optional[ast.AST]]] = {}

    trees = {}
    for path in sorted(pkg.glob("*.py")):
        tree = ctx.tree(path)
        if tree is None:
            continue
        trees[path] = tree
        rel = ctx.rel(Path(path))
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                classes[node.name] = _Class(node, rel)
            elif isinstance(node, ast.FunctionDef):
                factories.setdefault(node.name, node)

    for path, tree in trees.items():
        rel = ctx.rel(Path(path))
        # decorator form: @register("x") on a factory def
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.ClassDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) \
                            and last_segment(dotted(dec.func)) \
                            == "register" and dec.args \
                            and isinstance(dec.args[0], ast.Constant) \
                            and isinstance(dec.args[0].value, str):
                        registered[dec.args[0].value] = (
                            rel, dec.lineno, node)
            # call form: register("x")(factory_expr)
            if isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Call) \
                    and last_segment(dotted(node.func.func)) \
                    == "register" \
                    and node.func.args \
                    and isinstance(node.func.args[0], ast.Constant) \
                    and isinstance(node.func.args[0].value, str):
                target: Optional[ast.AST] = None
                if node.args:
                    arg = node.args[0]
                    if isinstance(arg, ast.Name):
                        target = factories.get(arg.id)
                    elif isinstance(arg, ast.Call):
                        fac = last_segment(dotted(arg.func))
                        target = factories.get(fac, arg)
                registered[node.func.args[0].value] = (
                    rel, node.lineno, target)

    # (1) every registration resolves to a class with real bits_per_client
    for name, (rel, line, target) in sorted(registered.items()):
        if target is None:
            continue
        insts = _instantiated_classes(target, classes)
        if insts and not any(_defines_real_bits(c, classes)
                             for c in insts):
            findings.append(Finding(
                "bits-accounting", rel, line,
                f"registered compressor `{name}` resolves to "
                f"{sorted(insts)} which define(s) no real "
                f"bits_per_client"))

    # (1b) every registration's compress builds a wire payload
    for name, (rel, line, target) in sorted(registered.items()):
        if target is None:
            continue
        for cname in sorted(_instantiated_classes(target, classes)):
            fn = _find_method(cname, "compress", classes)
            if fn is not None and not _pure_raise(fn) \
                    and not _builds_payload(fn):
                findings.append(Finding(
                    "bits-accounting", rel, line,
                    f"registered compressor `{name}` ({cname}.compress) "
                    f"builds no WirePayload (wire.pack_* / pack_wire) — "
                    f"transported bytes cannot match reported bits"))

    # (2) every public Compressor subclass has a real bits_per_client
    for cname, cls in sorted(classes.items()):
        if cname.startswith("_") or cname == "Compressor":
            continue
        if _derives_from_compressor(cname, classes) \
                and not _defines_real_bits(cname, classes):
            findings.append(Finding(
                "bits-accounting", cls.rel, cls.node.lineno,
                f"compressor class `{cname}` neither defines nor "
                f"inherits a real bits_per_client"))

    # (2b) class-level block literals match wire.SCALE_BLOCK
    sb = _scale_block(ctx)
    for cname, cls in sorted(classes.items()):
        if cname == "Compressor" \
                or not _derives_from_compressor(cname, classes):
            continue
        lit = _block_literal(cls)
        if lit is not None and lit[0] != sb:
            findings.append(Finding(
                "bits-accounting", cls.rel, lit[1],
                f"compressor class `{cname}` sets block={lit[0]} but the "
                f"wire scale stream is one f32 per SCALE_BLOCK={sb} "
                f"elements — payload scales would misalign"))

    # (3) docs table <-> registry set equality
    rows = _doc_table(ctx)
    doc_names = {n for n, _ in rows}
    if registered:
        for name, (rel, line, _) in sorted(registered.items()):
            if name not in doc_names:
                findings.append(Finding(
                    "bits-accounting", rel, line,
                    f"registered compressor `{name}` is missing from "
                    f"the {DOCS} built-in algorithms table"))
        for name, line in rows:
            if name not in registered:
                findings.append(Finding(
                    "bits-accounting", DOCS, line,
                    f"docs table row `{name}` names no registered "
                    f"compressor (doc-code drift)"))
    return findings
