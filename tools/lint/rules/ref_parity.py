"""ref-parity: every public kernel op needs an oracle and a parity test.

For each kernel family ``src/repro/kernels/<fam>/`` with an ``ops.py``:

* every public top-level def in ``ops.py`` that touches jax/jnp (the
  "ops") must have a same-named reference in the sibling ``ref.py`` —
  ``<op>_ref``, with a trailing ``_kernel`` suffix stripped first
  (``select_tau_kernel`` pairs with ``select_tau_ref``);
* the op must be *referenced from test code* in ``tests/test_kernels.py``
  or ``tests/test_sparsify_dispatch.py``.  References are collected from
  the test ASTs (every Name and attribute access), so a mention in a
  docstring does not count — only code that can actually exercise the op.

Pure-Python helpers in ops.py (no jax/jnp in the body) are exempt: they
are contracts' constants, not kernels.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Set

from tools.lint.core import Context, Finding, rule

TEST_FILES = ("tests/test_kernels.py", "tests/test_sparsify_dispatch.py")


def _code_identifiers(ctx: Context, paths) -> Set[str]:
    ids: Set[str] = set()
    for rel in paths:
        tree = ctx.tree(ctx.root / rel)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Name):
                ids.add(node.id)
            elif isinstance(node, ast.Attribute):
                ids.add(node.attr)
    return ids


def _uses_jax(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id in ("jax", "jnp"):
            return True
    return False


@rule("ref-parity",
      "every public kernels/*/ops.py op has a same-named ref.py oracle "
      "and a test that references it")
def check(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    kdir = ctx.root / "src" / "repro" / "kernels"
    if not kdir.is_dir():
        return findings
    test_ids = _code_identifiers(ctx, TEST_FILES)
    for fam in sorted(p for p in kdir.iterdir() if p.is_dir()):
        ops_path = fam / "ops.py"
        if not ops_path.exists():
            continue
        ops_tree = ctx.tree(ops_path)
        if ops_tree is None:
            continue
        rel_ops = ctx.rel(ops_path)
        ref_path = fam / "ref.py"
        ref_tree = ctx.tree(ref_path) if ref_path.exists() else None
        if ref_tree is None:
            findings.append(Finding(
                "ref-parity", rel_ops, 0,
                f"kernel family {fam.name!r} has ops.py but no ref.py "
                f"oracle module"))
        ref_names = {n.name for n in (ref_tree.body if ref_tree else [])
                     if isinstance(n, ast.FunctionDef)}
        for node in ops_tree.body:
            if not isinstance(node, ast.FunctionDef) \
                    or node.name.startswith("_") or not _uses_jax(node):
                continue
            base = node.name
            if base.endswith("_kernel"):
                base = base[: -len("_kernel")]
            want = base + "_ref"
            if ref_tree is not None and want not in ref_names \
                    and node.name + "_ref" not in ref_names:
                findings.append(Finding(
                    "ref-parity", rel_ops, node.lineno,
                    f"op `{node.name}` has no `{want}` oracle in "
                    f"{fam.name}/ref.py"))
            if node.name not in test_ids:
                findings.append(Finding(
                    "ref-parity", rel_ops, node.lineno,
                    f"op `{node.name}` is not referenced by any parity "
                    f"test in {' or '.join(TEST_FILES)}"))
    return findings
