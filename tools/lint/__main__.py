"""CLI: ``python -m tools.lint [--json] [--root DIR] ...``.

Exit codes: 0 = clean (possibly via baseline/suppressions), 1 = findings
or stale baseline entries, 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.lint import core


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: static contract checks (see "
                    "docs/linting.md)")
    parser.add_argument("--root", type=Path,
                        default=Path(__file__).resolve().parents[2],
                        help="repo root to lint (default: this repo)")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable JSON report on stdout")
    parser.add_argument("--rules", default="",
                        help="comma-separated subset of rules to run")
    parser.add_argument("--baseline", type=Path,
                        default=core.DEFAULT_BASELINE,
                        help="baseline file (use /dev/null to disable)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept all current findings into the "
                             "baseline file and exit 0")
    parser.add_argument("--vmem-budget-mb", type=float, default=16.0,
                        help="pallas-contract per-launch VMEM budget "
                             "(MiB, default 16)")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    core._load_rules()
    if args.list_rules:
        for name in sorted(core.RULES):
            print(f"{name:18s} {core.RULES[name][1]}")
        return 0

    rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        result = core.run_lint(args.root, rules or None,
                               baseline_path=args.baseline,
                               vmem_budget_mb=args.vmem_budget_mb)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    if args.write_baseline:
        old = core.load_baseline(args.baseline)
        core.write_baseline(args.baseline,
                            result.findings + result.baselined, old)
        print(f"[lint] baseline written to {args.baseline} "
              f"({len(result.findings) + len(result.baselined)} "
              f"entr(y/ies))")
        return 0

    if args.json:
        print(json.dumps(result.to_json(), indent=2))
    else:
        for f in result.findings:
            print(f)
        for e in result.stale_baseline:
            print(f"{e['path']}: [stale-baseline] baseline entry no "
                  f"longer matches any finding: [{e['rule']}] "
                  f"{e['message']}")
        print(f"[lint] {len(result.findings)} finding(s), "
              f"{len(result.baselined)} baselined, "
              f"{len(result.suppressed)} suppressed, "
              f"{len(result.stale_baseline)} stale baseline entr(y/ies)")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
