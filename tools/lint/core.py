"""Finding model, suppression comments, baseline file, and the runner.

Design notes
------------
* Findings are keyed ``(rule, path, message)`` — deliberately *not* on the
  line number, so the committed baseline survives unrelated edits that
  shift lines.  Messages therefore embed the symbol they refer to rather
  than relying on position.
* Suppressions are per-line comments, ``# repro-lint: disable=<rule>``
  (comma-separate to silence several rules; anything after the rule list
  is a free-form justification).  A suppression applies to findings whose
  anchor line is the comment's line.
* The baseline (``tools/lint/baseline.json``) holds *accepted* findings
  with a human justification.  Entries that no longer match any current
  finding are STALE and fail the run — the baseline can only shrink
  honestly.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Tuple

LINT_VERSION = 1
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"
SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,-]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str            # repo-root-relative, posix separators
    line: int            # 1-based anchor; 0 = file-level
    message: str

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.message)

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path,
                "line": self.line, "message": self.message}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Context:
    """Shared per-run state handed to every rule: root, config, and a
    source/AST cache so multi-rule runs parse each file once."""

    def __init__(self, root: Path, vmem_budget_mb: float = 16.0):
        self.root = Path(root).resolve()
        self.vmem_budget_mb = vmem_budget_mb
        self._src: Dict[Path, Optional[str]] = {}
        self._ast: Dict[Path, Optional[ast.Module]] = {}
        self.parse_errors: List[Finding] = []

    def rel(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root).as_posix()
        except ValueError:
            return path.as_posix()

    def source(self, path: Path) -> Optional[str]:
        path = Path(path)
        if path not in self._src:
            try:
                self._src[path] = path.read_text(encoding="utf-8")
            except OSError:
                self._src[path] = None
        return self._src[path]

    def tree(self, path: Path) -> Optional[ast.Module]:
        path = Path(path)
        if path not in self._ast:
            src = self.source(path)
            if src is None:
                self._ast[path] = None
            else:
                try:
                    self._ast[path] = ast.parse(src, filename=str(path))
                except SyntaxError as e:
                    self._ast[path] = None
                    self.parse_errors.append(Finding(
                        "parse", self.rel(path), e.lineno or 0,
                        f"syntax error: {e.msg}"))
        return self._ast[path]

    def suppressions(self, path: Path) -> Dict[int, set]:
        """line -> set of rule names disabled on that line."""
        src = self.source(path)
        out: Dict[int, set] = {}
        if src is None:
            return out
        for i, text in enumerate(src.splitlines(), start=1):
            m = SUPPRESS_RE.search(text)
            if m:
                out[i] = {r.strip() for r in m.group(1).split(",")
                          if r.strip()}
        return out


# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

RULES: Dict[str, Tuple[Callable[[Context], List[Finding]], str]] = {}


def rule(name: str, doc: str):
    def deco(fn):
        RULES[name] = (fn, doc)
        return fn
    return deco


def _load_rules() -> None:
    # import for side effect: each module registers itself via @rule
    from tools.lint.rules import (bits_accounting, jit_hazard,  # noqa: F401
                                  pallas_contract, ref_parity)


# --------------------------------------------------------------------------
# Baseline
# --------------------------------------------------------------------------


def load_baseline(path: Path) -> List[dict]:
    if not Path(path).exists():
        return []
    data = json.loads(Path(path).read_text(encoding="utf-8"))
    entries = data.get("findings", [])
    for e in entries:
        for field in ("rule", "path", "message"):
            if field not in e:
                raise ValueError(
                    f"baseline entry missing {field!r}: {e}")
    return entries


def write_baseline(path: Path, findings: Iterable[Finding],
                   old_entries: Iterable[dict] = ()) -> None:
    keep_just = {(e["rule"], e["path"], e["message"]):
                 e.get("justification", "") for e in old_entries}
    entries = [{"rule": f.rule, "path": f.path, "message": f.message,
                "justification": keep_just.get(
                    f.key, "TODO: justify or fix")}
               for f in sorted(set(findings),
                               key=lambda f: (f.path, f.rule, f.message))]
    payload = {"lint_version": LINT_VERSION, "findings": entries}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n",
                          encoding="utf-8")


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]          # actionable (fail the run)
    baselined: List[Finding]         # matched a baseline entry
    suppressed: List[Finding]        # silenced by an inline comment
    stale_baseline: List[dict]       # baseline entries nothing matched

    @property
    def ok(self) -> bool:
        return not self.findings and not self.stale_baseline

    def to_json(self) -> dict:
        return {
            "lint_version": LINT_VERSION,
            "ok": self.ok,
            "findings": [f.to_json() for f in self.findings],
            "baselined": [f.to_json() for f in self.baselined],
            "suppressed": [f.to_json() for f in self.suppressed],
            "stale_baseline": list(self.stale_baseline),
        }


def run_lint(root: Path, rules: Optional[Iterable[str]] = None,
             baseline_path: Optional[Path] = DEFAULT_BASELINE,
             vmem_budget_mb: float = 16.0) -> LintResult:
    """Run the selected rules rooted at ``root`` and triage the findings
    into actionable / suppressed / baselined buckets."""
    _load_rules()
    names = list(rules) if rules else sorted(RULES)
    unknown = [n for n in names if n not in RULES]
    if unknown:
        raise KeyError(f"unknown rule(s): {', '.join(unknown)}; "
                       f"available: {', '.join(sorted(RULES))}")

    ctx = Context(root, vmem_budget_mb=vmem_budget_mb)
    raw: List[Finding] = []
    for name in names:
        fn, _ = RULES[name]
        raw.extend(fn(ctx))
    raw.extend(ctx.parse_errors)
    raw = sorted(set(raw), key=lambda f: (f.path, f.line, f.rule, f.message))

    suppressed, live = [], []
    supp_cache: Dict[str, Dict[int, set]] = {}
    for f in raw:
        if f.path not in supp_cache:
            supp_cache[f.path] = ctx.suppressions(ctx.root / f.path)
        disabled = supp_cache[f.path].get(f.line, set())
        (suppressed if f.rule in disabled else live).append(f)

    entries = load_baseline(baseline_path) if baseline_path else []
    base_keys = {(e["rule"], e["path"], e["message"]) for e in entries}
    matched_keys = set()
    findings, baselined = [], []
    for f in live:
        if f.key in base_keys:
            baselined.append(f)
            matched_keys.add(f.key)
        else:
            findings.append(f)
    stale = [e for e in entries
             if (e["rule"], e["path"], e["message"]) not in matched_keys]
    return LintResult(findings=findings, baselined=baselined,
                      suppressed=suppressed, stale_baseline=stale)
