"""Shared AST helpers: dotted-name rendering, a constant-folding
environment over module-level assignments, and ordered statement walks."""
from __future__ import annotations

import ast
from typing import Any, Dict, Iterator, Optional


def dotted(node: ast.AST) -> Optional[str]:
    """Render ``pl.BlockSpec``-style Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_segment(name: Optional[str]) -> Optional[str]:
    return name.rsplit(".", 1)[-1] if name else None


class ConstEnv:
    """Best-effort evaluator for module-level integer/float/tuple
    constants (``LANES = 1024``, ``BLOCK = (SUBLANES, LANES)``, ...).
    Anything unresolvable evaluates to None."""

    def __init__(self) -> None:
        self.env: Dict[str, Any] = {}

    def load_module(self, tree: ast.Module) -> None:
        for node in tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                val = self.eval(node.value)
                if val is not None:
                    self.env[node.targets[0].id] = val

    def eval(self, node: ast.AST) -> Any:
        if isinstance(node, ast.Constant) and isinstance(
                node.value, (int, float)) and not isinstance(
                node.value, bool):
            return node.value
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.Tuple):
            vals = tuple(self.eval(e) for e in node.elts)
            return None if any(v is None for v in vals) else vals
        if isinstance(node, ast.BinOp):
            lhs, rhs = self.eval(node.left), self.eval(node.right)
            if lhs is None or rhs is None:
                return None
            try:
                if isinstance(node.op, ast.Add):
                    return lhs + rhs
                if isinstance(node.op, ast.Sub):
                    return lhs - rhs
                if isinstance(node.op, ast.Mult):
                    return lhs * rhs
                if isinstance(node.op, ast.FloorDiv):
                    return lhs // rhs
                if isinstance(node.op, ast.Div):
                    return lhs / rhs
                if isinstance(node.op, ast.Pow):
                    return lhs ** rhs
                if isinstance(node.op, ast.LShift):
                    return lhs << rhs
                if isinstance(node.op, ast.Mod):
                    return lhs % rhs
            except (TypeError, ZeroDivisionError, ValueError):
                return None
            return None
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            val = self.eval(node.operand)
            return None if val is None else -val
        if isinstance(node, ast.Call):
            fn = last_segment(dotted(node.func))
            args = [self.eval(a) for a in node.args]
            if fn in ("min", "max", "abs", "round", "int", "len") \
                    and args and all(a is not None for a in args):
                try:
                    if fn == "len":
                        return None  # len of a const tuple is rare; skip
                    return {"min": min, "max": max, "abs": abs,
                            "round": round, "int": int}[fn](*args)
                except (TypeError, ValueError):
                    return None
        return None


def walk_statements(body) -> Iterator[ast.stmt]:
    """Yield statements in source order, descending into compound
    statements but NOT into nested function/class definitions."""
    for stmt in body:
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            yield from walk_statements(getattr(stmt, field, []))
        for handler in getattr(stmt, "handlers", []):
            yield from walk_statements(handler.body)


def walk_own(fn: ast.AST) -> Iterator[ast.AST]:
    """ast.walk restricted to ``fn``'s own code: does not descend into
    nested def/class bodies (lambdas ARE descended — they trace inline)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))
