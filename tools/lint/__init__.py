"""repro-lint: stdlib-ast static analysis guarding the repo's contracts.

Four rules (see docs/linting.md):

* ``pallas-contract``  — BlockSpec tile alignment + per-launch VMEM budget
* ``jit-hazard``       — host-sync / recompile triggers inside traced code
* ``ref-parity``       — every public kernel op has a ref.py oracle and a
  parity test that references it
* ``bits-accounting``  — registry / ``bits_per_client`` / docs-table drift

Run ``python -m tools.lint --help``.  No third-party dependencies; the
analyzed code is never imported.
"""
from tools.lint.core import Finding, LintResult, run_lint  # noqa: F401
